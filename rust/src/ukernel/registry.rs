//! Data-driven micro-kernel registry — the BLAS analogue of
//! [`crate::arch::PlatformRegistry`] and [`crate::net::FabricRegistry`].
//!
//! A [`KernelDescriptor`] bundles identity (id, label, aliases) with a
//! generator family ([`KernelFamily`]: `openblas-asm` | `blis-rvv` |
//! `asm-source`) and
//! the tunable parameters the paper's BLAS exploration varies: VLEN,
//! LMUL, the MRxNR register tile, the K-unroll depth, the blocking
//! policy and the calibrated host (packing/framework) overhead.
//! Descriptors self-validate as typed [`CimoneError::InvalidKernel`]
//! (register-file overflow, unsupported VLEN, broken tiles are
//! load-time errors) and are registered by string id or alias in a
//! [`KernelRegistry`]. The built-ins:
//!
//! | id                | generator     | parameters            | paper role                |
//! |-------------------|---------------|-----------------------|---------------------------|
//! | `openblas-generic`| openblas-asm  | scalar (VLEN=0), 4x4  | no-vector baseline        |
//! | `openblas-c920`   | openblas-asm  | VLEN=128 LMUL=2, 8x4  | SG2042-optimized asm      |
//! | `blis-lmul1`      | blis-rvv      | VLEN=128 LMUL=1, 8x4  | BLIS shipped (Fig 2a)     |
//! | `blis-lmul4`      | blis-rvv      | VLEN=128 LMUL=4, 8x4  | the paper's kernel (Fig 2b)|
//! | `blis-rvv1-lmul2` | blis-rvv      | VLEN=128 LMUL=2, u4   | SG2044 native RVV 1.0     |
//! | `blis-rvv1-lmul4` | blis-rvv      | VLEN=128 LMUL=4, u2   | MCv3 native RVV 1.0       |
//! | `blis-rvv1-vl256` | blis-rvv      | VLEN=256 LMUL=4, 16x4 | C930-class what-if        |
//!
//! The four paper kernels produce bit-identical programs to the seed's
//! hand-written modules (pinned in `rust/tests/integration_kernels.rs`);
//! the two `blis-rvv1-*` kernels are the native RVV 1.0 tuning points
//! of arXiv 2508.13840 / 2605.22831 — no retrofit glue, deeper K-unroll,
//! packing tuned for the C920v2's doubled per-cluster L2 — which is why
//! their calibrated host overheads sit below the retrofit kernels'.

use std::collections::BTreeMap;
use std::sync::Arc;

use std::path::Path;

use super::generators;
use super::layout::PanelLayout;
use crate::error::CimoneError;
use crate::isa::assembler::{assemble_kernel, AsmKernel};
use crate::isa::exec::VecMachine;
use crate::isa::inst::{Dialect, Program};
use crate::isa::rvv::{Lmul, Sew};
use crate::util::config::Section;
use crate::util::hash::ContentHasher;
use crate::util::Matrix;

/// Stable hash code for an LMUL setting (total — [`Lmul::Fractional`]
/// never validates into a registry but must still feed deterministically).
fn lmul_code(l: Lmul) -> usize {
    match l {
        Lmul::M1 => 1,
        Lmul::M2 => 2,
        Lmul::M4 => 4,
        Lmul::M8 => 8,
        Lmul::Fractional => 255,
    }
}

/// Which program generator emits the kernel's instruction schedule.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum KernelFamily {
    /// OpenBLAS hand-scheduled asm: software-pipelined scalar loads
    /// (scalar `fmadd.d` kernel when VLEN = 0).
    OpenblasAsm,
    /// BLIS rank-1-update RVV kernel (the Fig 2 schedule family).
    BlisRvv,
    /// A real assembly listing ingested by [`crate::isa::assembler`]:
    /// the program comes from an inline `source = '''...'''` block or a
    /// `path = "..."` file in the `[[kernel]]` spec section, not from a
    /// generator. This is how published OpenBLAS/BLIS `.S` micro-kernels
    /// enter a sweep with zero Rust edits.
    AsmSource,
}

impl KernelFamily {
    /// Canonical spec-file spelling.
    pub fn spec_name(&self) -> &'static str {
        match self {
            KernelFamily::OpenblasAsm => "openblas-asm",
            KernelFamily::BlisRvv => "blis-rvv",
            KernelFamily::AsmSource => "asm-source",
        }
    }

    pub fn parse(s: &str) -> Option<KernelFamily> {
        match s {
            "openblas-asm" => Some(KernelFamily::OpenblasAsm),
            "blis-rvv" => Some(KernelFamily::BlisRvv),
            "asm-source" => Some(KernelFamily::AsmSource),
            _ => None,
        }
    }
}

/// The resolved assembly behind an `asm-source` kernel: the listing text
/// and where it came from, plus the assembled [`AsmKernel`] unit.
///
/// Equality and the cache content feed go through the *assembled unit*
/// only: two listings that differ in comments, label spelling or
/// whitespace — or the same kernel loaded from a file vs. re-parsed out
/// of a rendered spec — are the same kernel, with the same content
/// digest. That is what keeps PR 6's warm-cache bit-identity guarantee
/// intact across `render()` round trips.
#[derive(Debug, Clone)]
pub struct AsmSource {
    /// Where the listing came from (`<spec>` for inline sources).
    pub file: String,
    /// The raw listing text, kept for `render()` round trips.
    pub text: String,
    /// The assembled micro-kernel unit.
    pub unit: AsmKernel,
}

impl PartialEq for AsmSource {
    fn eq(&self, other: &Self) -> bool {
        self.unit == other.unit
    }
}

impl AsmSource {
    /// Assemble `text` into kernel form. `file` labels errors.
    pub fn assemble(text: &str, file: &str) -> Result<AsmSource, CimoneError> {
        let unit = assemble_kernel(text, file)?;
        Ok(AsmSource { file: file.to_string(), text: text.to_string(), unit })
    }
}

/// How the library derives its MC/KC/NC cache blocking.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BlockingPolicy {
    /// BLIS's analytical model: derive from the socket's cache geometry.
    CacheDerived,
    /// OpenBLAS's fixed x86-tuned `param.h` constants.
    Fixed,
}

impl BlockingPolicy {
    /// Canonical spec-file spelling.
    pub fn spec_name(&self) -> &'static str {
        match self {
            BlockingPolicy::CacheDerived => "cache-derived",
            BlockingPolicy::Fixed => "fixed",
        }
    }

    pub fn parse(s: &str) -> Option<BlockingPolicy> {
        match s {
            "cache-derived" => Some(BlockingPolicy::CacheDerived),
            "fixed" => Some(BlockingPolicy::Fixed),
            _ => None,
        }
    }
}

/// One registrable GEMM micro-kernel: identity + generator family +
/// tunables. The descriptor IS the kernel — `program`/`run` generate
/// and execute its schedule directly.
#[derive(Debug, Clone, PartialEq)]
pub struct KernelDescriptor {
    /// Registry key and spec-file spelling (e.g. `blis-lmul4`).
    pub id: String,
    /// Human label used in reports (e.g. `BLIS (optimized, LMUL=4)`).
    pub label: String,
    /// Alternate spec-file spellings (`blis-opt`, `openblas`, ...).
    pub aliases: Vec<String>,
    /// Which generator emits the instruction schedule.
    pub family: KernelFamily,
    /// Vector register length in bits; 0 = scalar kernel (no RVV).
    /// Any power of two >= 64 is accepted — the functional machine and
    /// cycle model are VLEN-generic.
    pub vlen_bits: usize,
    /// Register-group multiplier (ignored by scalar kernels).
    pub lmul: Lmul,
    /// Element width the kernel computes at. E64 is classic DGEMM (all
    /// built-ins); E32 is the single-precision kernel behind the
    /// HPL-MxP mixed-precision workload — same schedule, twice the
    /// elements per register group. Scalar (VLEN=0) kernels are
    /// FP64-only, enforced by [`KernelDescriptor::validate`].
    pub sew: Sew,
    /// Was the kernel tuned (and its `host_overhead` calibrated) for a
    /// ratified-RVV 1.0 pipeline? The paper's four kernels carry
    /// `false` — they are 0.7.1-era code (OpenBLAS's theadvector asm,
    /// BLIS's rv64iv source run through the Section 3.3.1 retrofit).
    /// Running a vector kernel on the *other* dialect's core pays the
    /// port tax in [`crate::ukernel::analysis::PORT_TAX`]; scalar
    /// kernels (VLEN=0) are portable C and never do.
    pub native_rvv10: bool,
    /// Register-tile rows (elements of C per column group run).
    pub mr: usize,
    /// Register-tile columns.
    pub nr: usize,
    /// K-steps per unrolled loop body (>= 1); deeper unroll amortizes
    /// the pointer-bump/branch bookkeeping.
    pub k_unroll: usize,
    /// Cache-blocking derivation policy.
    pub blocking: BlockingPolicy,
    /// Fraction of end-to-end DGEMM time spent *outside* the
    /// micro-kernel (packing, edge tiles, framework dispatch), in
    /// [0, 1). Calibrated per library — see EXPERIMENTS.md 'Calibration'.
    pub host_overhead: f64,
    /// The assembled listing behind an [`KernelFamily::AsmSource`]
    /// kernel; `None` for the generator families. `Arc`-shared so
    /// cloning descriptors through spec round trips stays cheap.
    pub asm: Option<Arc<AsmSource>>,
}

impl KernelDescriptor {
    /// Does `name` refer to this kernel (id or alias)?
    pub fn matches(&self, name: &str) -> bool {
        self.id == name || self.aliases.iter().any(|a| a == name)
    }

    /// Native register-tile geometry (mr, nr).
    pub fn tile(&self) -> (usize, usize) {
        (self.mr, self.nr)
    }

    /// Canonical content feed for the estimation cache: identity plus
    /// every tunable the generators and the cycle model read. Cosmetic
    /// fields (label, aliases) are excluded.
    pub fn feed_content(&self, h: &mut ContentHasher) {
        h.write_str(&self.id);
        h.write_str(self.family.spec_name());
        h.write_usize(self.vlen_bits);
        h.write_usize(lmul_code(self.lmul));
        h.write_bool(self.native_rvv10);
        h.write_usize(self.mr).write_usize(self.nr).write_usize(self.k_unroll);
        h.write_str(self.blocking.spec_name());
        h.write_f64(self.host_overhead);
        // element width changes every generated program and timing —
        // it MUST shift the content digest (warm-cache bit-identity)
        h.write_usize(self.sew.bits());
        // asm-source kernels: the *assembled unit* feeds (canonical
        // per-inst render), so comment/whitespace edits to a listing
        // never shift cache keys
        if let Some(a) = &self.asm {
            a.unit.feed_content(h);
        }
    }

    /// The 128-bit content digest of [`KernelDescriptor::feed_content`].
    pub fn content_hash(&self) -> u128 {
        let mut h = ContentHasher::new();
        self.feed_content(&mut h);
        h.finish()
    }

    fn err(&self, reason: impl Into<String>) -> CimoneError {
        CimoneError::InvalidKernel { id: self.id.clone(), reason: reason.into() }
    }

    /// Check the descriptor's own invariants; every registration path
    /// runs this, so malformed kernels never reach the generators. This
    /// is also where the paper's implicit LMUL=8 rejection lives: a
    /// configuration whose accumulator + A-column groups overflow the
    /// 32-register file is a typed error, not a miscompiled schedule.
    pub fn validate(&self) -> Result<(), CimoneError> {
        if self.id.is_empty() || self.id.contains(char::is_whitespace) {
            return Err(self.err("id must be non-empty and free of whitespace"));
        }
        if self.mr == 0 || self.nr == 0 {
            return Err(self.err("register tile must be non-empty (mr, nr >= 1)"));
        }
        if self.k_unroll == 0 {
            return Err(self.err("k_unroll must be >= 1"));
        }
        if !(self.host_overhead >= 0.0 && self.host_overhead < 1.0) {
            return Err(self.err("host_overhead must be in [0, 1)"));
        }
        if self.lmul.is_fractional() {
            return Err(self.err("fractional LMUL is not a GEMM-kernel configuration"));
        }
        if self.asm.is_some() && self.family != KernelFamily::AsmSource {
            return Err(self.err(format!(
                "family `{}` does not take an assembly listing (use family = \"asm-source\")",
                self.family.spec_name()
            )));
        }
        if self.vlen_bits == 0 {
            // scalar path: accumulators live in f16..f31, A in f0..,
            // B in f{mr}..
            if self.family != KernelFamily::OpenblasAsm {
                return Err(self.err("VLEN=0 (scalar) is only an openblas-asm configuration"));
            }
            if self.sew != Sew::E64 {
                return Err(self.err(
                    "sew = 32 needs a vector kernel (vlen >= 64) — the scalar \
                     fmadd.d path is FP64-only",
                ));
            }
            if self.mr * self.nr > 16 {
                return Err(self
                    .err(format!("scalar {}x{} tile overflows f16..f31", self.mr, self.nr)));
            }
            if self.mr + self.nr > 16 {
                return Err(self.err("scalar A column + B row overflow f0..f15"));
            }
            return Ok(());
        }
        if self.vlen_bits < 64
            || self.vlen_bits > crate::isa::exec::MAX_VLEN_BITS
            || !self.vlen_bits.is_power_of_two()
        {
            return Err(self.err(format!(
                "unsupported VLEN {} (need 0 for scalar, or a power of two in 64..={})",
                self.vlen_bits,
                crate::isa::exec::MAX_VLEN_BITS
            )));
        }
        if self.nr > 16 {
            return Err(self.err("nr > 16 overflows the B-scalar FP registers"));
        }
        if self.family == KernelFamily::AsmSource {
            let src = self
                .asm
                .as_ref()
                .ok_or_else(|| self.err("asm-source kernel without an assembled listing"))?;
            // an assembly listing fixes its own element widths per
            // instruction — the descriptor-level sew knob is for the
            // generator families only
            if self.sew != Sew::E64 {
                return Err(self.err(
                    "asm-source kernels carry their element width in the listing \
                     (sew overrides apply to generator families only)",
                ));
            }
            // dialect consistency: a theadvector listing cannot claim to
            // be native RVV 1.0 code (PORT_TAX would be mischarged)
            if src.unit.dialect == Dialect::Thead071 && self.native_rvv10 {
                return Err(self.err(format!(
                    "{}: theadvector listing with native_rvv10 = true — a 0.7.1 \
                     source is not native RVV 1.0 code",
                    src.file
                )));
            }
            // panel-offset bounds, vsetvli feasibility at this VLEN, and
            // register-group legality of the expanded program
            return src
                .unit
                .check(self.mr, self.nr, self.k_unroll, self.vlen_bits)
                .map_err(|reason| self.err(format!("{}: {reason}", src.file)));
        }
        let g = match self.family {
            KernelFamily::BlisRvv => generators::blis_geometry_sew(
                self.vlen_bits,
                self.lmul,
                self.sew,
                self.mr,
                self.nr,
            ),
            KernelFamily::OpenblasAsm => generators::openblas_geometry_sew(
                self.vlen_bits,
                self.lmul,
                self.sew,
                self.mr,
                self.nr,
            ),
            KernelFamily::AsmSource => unreachable!("handled above"),
        };
        if self.mr > g.elems_per_group && self.mr % g.elems_per_group != 0 {
            return Err(self.err(format!(
                "mr={} is not a multiple of the {}-element register group",
                self.mr, g.elems_per_group
            )));
        }
        if g.regs_used > 32 {
            return Err(self.err(format!(
                "register allocation needs v0..v{} — overflows the 32-register file \
                 (the constraint that stops the paper at LMUL=4)",
                g.regs_used - 1
            )));
        }
        Ok(())
    }

    /// Emit the full micro-kernel program for the layout's KC rank-1
    /// update steps.
    pub fn program(&self, l: PanelLayout) -> Program {
        assert_eq!((l.mr, l.nr), (self.mr, self.nr), "{}: layout/tile mismatch", self.id);
        match self.family {
            KernelFamily::BlisRvv => generators::blis_rvv_program_sew(
                self.vlen_bits,
                self.lmul,
                self.sew,
                self.k_unroll,
                l,
            ),
            KernelFamily::OpenblasAsm => generators::openblas_asm_program_sew(
                self.vlen_bits,
                self.lmul,
                self.sew,
                self.k_unroll,
                l,
            ),
            KernelFamily::AsmSource => self
                .asm
                .as_ref()
                .expect("validated: asm-source kernels carry their listing")
                .unit
                .expand(l, self.k_unroll),
        }
    }

    /// Execute the kernel on real data via the functional machine (at
    /// the kernel's own VLEN). Returns the updated C tile. The program
    /// comes from the intern cache
    /// ([`crate::ukernel::analysis::interned_program`]), so repeated
    /// invocations at one shape decode the schedule exactly once.
    pub fn run(&self, a: &Matrix, b: &Matrix, c: &Matrix) -> Result<Matrix, CimoneError> {
        let layout = PanelLayout::new(self.mr, self.nr, a.cols());
        let prog = super::analysis::interned_program(self, layout);
        let mut m = VecMachine::new(self.vlen_bits.max(64), layout.mem_words())?;
        m.mem = layout.pack(a, b, c);
        m.run(&prog)?;
        Ok(layout.unpack_c(&m.mem))
    }
}

/// OpenBLAS built for the generic RV64 target — the paper's no-vector
/// baseline: "serving as a baseline that does not leverage the
/// processor's vector unit" (Section 3.2). Calibrated overhead ~16%:
/// the slow scalar inner loop makes framework time relatively small.
pub fn openblas_generic() -> KernelDescriptor {
    KernelDescriptor {
        id: "openblas-generic".into(),
        label: "OpenBLAS (generic RV64)".into(),
        aliases: vec!["generic".into()],
        family: KernelFamily::OpenblasAsm,
        vlen_bits: 0,
        lmul: Lmul::M1,
        sew: Sew::E64,
        native_rvv10: false,
        mr: 4,
        nr: 4,
        k_unroll: 1,
        blocking: BlockingPolicy::Fixed,
        host_overhead: 0.16,
        asm: None,
    }
}

/// OpenBLAS's SG2042-optimized DGEMM kernel (`dgemm_kernel_8x4_c920.S`):
/// LMUL=2 groups, software-pipelined scalar loads, native theadvector.
/// Calibrated overhead ~38%: its x86-ratio blocking is exactly the
/// inefficiency Fig 6 exposes.
pub fn openblas_c920() -> KernelDescriptor {
    KernelDescriptor {
        id: "openblas-c920".into(),
        label: "OpenBLAS (C920-optimized)".into(),
        aliases: vec!["openblas".into(), "openblas-opt".into()],
        family: KernelFamily::OpenblasAsm,
        vlen_bits: 128,
        lmul: Lmul::M2,
        sew: Sew::E64,
        native_rvv10: false,
        mr: 8,
        nr: 4,
        k_unroll: 1,
        blocking: BlockingPolicy::Fixed,
        host_overhead: 0.38,
        asm: None,
    }
}

/// BLIS's shipped rv64iv kernel — the Fig 2a schedule (LMUL=1, four
/// loads + four `vfmacc.vf` per column). Calibrated overhead ~35%.
pub fn blis_lmul1() -> KernelDescriptor {
    KernelDescriptor {
        id: "blis-lmul1".into(),
        label: "BLIS (vanilla RVV, LMUL=1)".into(),
        aliases: vec!["blis".into(), "blis-vanilla".into()],
        family: KernelFamily::BlisRvv,
        vlen_bits: 128,
        lmul: Lmul::M1,
        sew: Sew::E64,
        native_rvv10: false,
        mr: 8,
        nr: 4,
        k_unroll: 1,
        blocking: BlockingPolicy::CacheDerived,
        host_overhead: 0.35,
        asm: None,
    }
}

/// The paper's optimized BLIS kernel — the Fig 2b schedule (LMUL=4, one
/// load / one `vfmacc.vf` per column). Same blocking and algorithm as
/// [`blis_lmul1`]; only the schedule changes, which is the paper's
/// point. Calibrated overhead ~23% (longer effective inner loop).
pub fn blis_lmul4() -> KernelDescriptor {
    KernelDescriptor {
        id: "blis-lmul4".into(),
        label: "BLIS (optimized, LMUL=4)".into(),
        aliases: vec!["blis-opt".into()],
        family: KernelFamily::BlisRvv,
        vlen_bits: 128,
        lmul: Lmul::M4,
        sew: Sew::E64,
        native_rvv10: false,
        mr: 8,
        nr: 4,
        k_unroll: 1,
        blocking: BlockingPolicy::CacheDerived,
        host_overhead: 0.23,
        asm: None,
    }
}

/// BLIS tuned natively for the C920v2's ratified RVV 1.0 pipeline
/// (arXiv 2508.13840): with the reworked front end no longer
/// dispatch-bound, LMUL=2 suffices (halving accumulator register
/// pressure) and the win moves to a deeper K-unroll. Calibrated
/// overhead ~18% — no retrofit glue, packing tuned for the SG2044's
/// doubled per-cluster L2.
pub fn blis_rvv1_lmul2() -> KernelDescriptor {
    KernelDescriptor {
        id: "blis-rvv1-lmul2".into(),
        label: "BLIS (native RVV 1.0, LMUL=2)".into(),
        aliases: vec!["blis-rvv1".into()],
        family: KernelFamily::BlisRvv,
        vlen_bits: 128,
        lmul: Lmul::M2,
        sew: Sew::E64,
        native_rvv10: true,
        mr: 8,
        nr: 4,
        k_unroll: 4,
        blocking: BlockingPolicy::CacheDerived,
        host_overhead: 0.18,
        asm: None,
    }
}

/// The LMUL=4 native-RVV 1.0 tuning point (the MCv3 direction, arXiv
/// 2605.22831): keeps Fig 2b's minimal fetch bandwidth — what a
/// dual-socket node's contended front end still rewards — at a milder
/// unroll. Calibrated overhead ~20%.
pub fn blis_rvv1_lmul4() -> KernelDescriptor {
    KernelDescriptor {
        id: "blis-rvv1-lmul4".into(),
        label: "BLIS (native RVV 1.0, LMUL=4)".into(),
        aliases: vec![],
        family: KernelFamily::BlisRvv,
        vlen_bits: 128,
        lmul: Lmul::M4,
        sew: Sew::E64,
        native_rvv10: true,
        mr: 8,
        nr: 4,
        k_unroll: 2,
        blocking: BlockingPolicy::CacheDerived,
        host_overhead: 0.20,
        asm: None,
    }
}

/// The VLEN-256 C930-class tuning point (the wider-VLEN what-if left
/// open by the PR 5 notes): the Fig 2b minimal-fetch schedule of
/// [`blis_rvv1_lmul4`] widened to a 16x4 tile. At VLEN=256 an LMUL=4
/// group holds 16 doubles, so one `vle` + one `vfmacc.vf` per column
/// still covers the whole tile (accumulators in v0..v15, the A group at
/// v16). Calibrated overhead ~32%: packing 16-row A panels is
/// harsher on a 128-bit-era L2 than the 8-row retrofits, which is why
/// this kernel only pays off on cores with the matching 4-lane datapath.
pub fn blis_rvv1_vl256() -> KernelDescriptor {
    KernelDescriptor {
        id: "blis-rvv1-vl256".into(),
        label: "BLIS (native RVV 1.0, VLEN=256)".into(),
        aliases: vec!["blis-c930".into()],
        family: KernelFamily::BlisRvv,
        vlen_bits: 256,
        lmul: Lmul::M4,
        sew: Sew::E64,
        native_rvv10: true,
        mr: 16,
        nr: 4,
        k_unroll: 2,
        blocking: BlockingPolicy::CacheDerived,
        host_overhead: 0.32,
        asm: None,
    }
}

/// Kernels keyed by id, resolvable by id or alias.
#[derive(Debug, Clone, Default)]
pub struct KernelRegistry {
    by_id: BTreeMap<String, Arc<KernelDescriptor>>,
}

impl KernelRegistry {
    /// An empty registry.
    pub fn new() -> KernelRegistry {
        KernelRegistry::default()
    }

    /// The built-in kernels: the paper's four plus the native RVV 1.0
    /// tuning points.
    pub fn builtin() -> KernelRegistry {
        let mut reg = KernelRegistry::new();
        for k in [
            openblas_generic(),
            openblas_c920(),
            blis_lmul1(),
            blis_lmul4(),
            blis_rvv1_lmul2(),
            blis_rvv1_lmul4(),
            blis_rvv1_vl256(),
        ] {
            reg.register(k).expect("built-in kernels are valid and unique");
        }
        reg
    }

    /// Validate and add a kernel. Ids and aliases share one namespace;
    /// any clash with an already-registered name is rejected.
    pub fn register(
        &mut self,
        kernel: KernelDescriptor,
    ) -> Result<Arc<KernelDescriptor>, CimoneError> {
        kernel.validate()?;
        for name in std::iter::once(&kernel.id).chain(kernel.aliases.iter()) {
            if self.resolve(name).is_some() {
                return Err(CimoneError::DuplicateKernel(name.clone()));
            }
        }
        let arc = Arc::new(kernel);
        self.by_id.insert(arc.id.clone(), Arc::clone(&arc));
        Ok(arc)
    }

    fn resolve(&self, name: &str) -> Option<&Arc<KernelDescriptor>> {
        self.by_id.get(name).or_else(|| self.by_id.values().find(|k| k.matches(name)))
    }

    /// Look a kernel up by id or alias.
    pub fn get(&self, name: &str) -> Result<Arc<KernelDescriptor>, CimoneError> {
        self.resolve(name).cloned().ok_or_else(|| CimoneError::UnknownKernel {
            name: name.to_string(),
            known: self.ids().join(", "),
        })
    }

    pub fn contains(&self, name: &str) -> bool {
        self.resolve(name).is_some()
    }

    /// Registered ids, sorted.
    pub fn ids(&self) -> Vec<String> {
        self.by_id.keys().cloned().collect()
    }

    /// All registered kernels, in id order.
    pub fn kernels(&self) -> impl Iterator<Item = &Arc<KernelDescriptor>> {
        self.by_id.values()
    }

    /// Register a kernel described by a `[[kernel]]` campaign-spec
    /// section: a required `base` kernel (id or alias) plus overrides.
    ///
    /// ```text
    /// [[kernel]]
    /// id = "blis-rvv1-u8"
    /// base = "blis-rvv1-lmul2"
    /// k_unroll = 8
    /// # other overrides: label, family, vlen, lmul, sew, mr, nr,
    /// # blocking, host_overhead, native_rvv10
    /// ```
    pub fn register_section(
        &mut self,
        sec: &Section,
    ) -> Result<Arc<KernelDescriptor>, CimoneError> {
        self.register_section_with_dir(sec, None)
    }

    /// [`KernelRegistry::register_section`] with a base directory for
    /// resolving relative `path = "..."` listings (normally the spec
    /// file's own directory). `asm-source` kernels take their program
    /// from an inline `source = '''...'''` block or a `path` file:
    ///
    /// ```text
    /// [[kernel]]
    /// id = "dgemm-rvv1-8x8"
    /// base = "blis-rvv1-lmul2"
    /// family = "asm-source"
    /// path = "kernels/dgemm_rvv1_8x8.S"
    /// vlen = 256
    /// ```
    pub fn register_section_with_dir(
        &mut self,
        sec: &Section,
        dir: Option<&Path>,
    ) -> Result<Arc<KernelDescriptor>, CimoneError> {
        const KNOWN_KEYS: &[&str] = &[
            "id",
            "base",
            "label",
            "family",
            "vlen",
            "lmul",
            "sew",
            "mr",
            "nr",
            "k_unroll",
            "blocking",
            "host_overhead",
            "native_rvv10",
            "source",
            "path",
        ];
        let id = sec
            .get("id")
            .and_then(|v| v.as_str())
            .ok_or_else(|| CimoneError::Spec("[[kernel]]: missing string key `id`".into()))?
            .to_string();
        let spec_err =
            |msg: String| -> CimoneError { CimoneError::Spec(format!("kernel `{id}`: {msg}")) };
        // a misspelled override must be a load-time error, not a kernel
        // silently identical to its base
        if let Some(unknown) = sec.keys().find(|k| !KNOWN_KEYS.contains(&k.as_str())) {
            return Err(spec_err(format!(
                "unknown key `{unknown}` (known: {})",
                KNOWN_KEYS.join(", ")
            )));
        }
        let base = sec
            .get("base")
            .and_then(|v| v.as_str())
            .ok_or_else(|| spec_err("missing string key `base`".into()))?;
        let mut k: KernelDescriptor = (*self.get(base)?).clone();
        let base_label = k.label.clone();
        k.id = id.clone();
        k.aliases = Vec::new();
        k.label = format!("{id} (custom, from {base_label})");

        if let Some(v) = sec.get("label") {
            k.label =
                v.as_str().ok_or_else(|| spec_err("`label` must be a string".into()))?.to_string();
        }
        if let Some(v) = sec.get("family") {
            let s = v.as_str().ok_or_else(|| spec_err("`family` must be a string".into()))?;
            k.family = KernelFamily::parse(s).ok_or_else(|| {
                spec_err(format!("unknown family `{s}` (openblas-asm | blis-rvv | asm-source)"))
            })?;
        }
        if let Some(v) = sec.get("blocking") {
            let s = v.as_str().ok_or_else(|| spec_err("`blocking` must be a string".into()))?;
            k.blocking = BlockingPolicy::parse(s).ok_or_else(|| {
                spec_err(format!("unknown blocking `{s}` (cache-derived | fixed)"))
            })?;
        }
        if let Some(v) = sec.get("vlen") {
            // 0 = scalar; validate() enforces the power-of-two floor
            k.vlen_bits = v
                .as_int()
                .filter(|i| *i >= 0)
                .ok_or_else(|| spec_err("`vlen` must be a non-negative int".into()))?
                as usize;
        }
        if let Some(v) = sec.get("lmul") {
            let m = v.as_int().ok_or_else(|| spec_err("`lmul` must be an int (1|2|4|8)".into()))?;
            k.lmul = match m {
                1 => Lmul::M1,
                2 => Lmul::M2,
                4 => Lmul::M4,
                8 => Lmul::M8,
                other => return Err(spec_err(format!("`lmul` must be 1, 2, 4 or 8, got {other}"))),
            };
        }
        if let Some(v) = sec.get("sew") {
            let b = v.as_int().ok_or_else(|| spec_err("`sew` must be an int (32|64)".into()))?;
            k.sew = match b {
                32 => Sew::E32,
                64 => Sew::E64,
                other => return Err(spec_err(format!("`sew` must be 32 or 64, got {other}"))),
            };
        }
        let get_usize = |key: &str| -> Result<Option<usize>, CimoneError> {
            match sec.get(key) {
                None => Ok(None),
                Some(v) => v
                    .as_int()
                    .filter(|i| *i > 0)
                    .map(|i| Some(i as usize))
                    .ok_or_else(|| spec_err(format!("`{key}` must be a positive int"))),
            }
        };
        if let Some(v) = get_usize("mr")? {
            k.mr = v;
        }
        if let Some(v) = get_usize("nr")? {
            k.nr = v;
        }
        if let Some(v) = get_usize("k_unroll")? {
            k.k_unroll = v;
        }
        if let Some(v) = sec.get("host_overhead") {
            k.host_overhead = match v.as_str() {
                // `host_overhead = "auto"`: calibrate from the cache
                // simulator's L2/L3 miss rates on the reference SG2042
                // socket (the paper's calibration platform) — the
                // geometry overrides above are already applied, so the
                // simulated loop nest is the kernel's own
                Some("auto") => super::analysis::calibrated_host_overhead(
                    &k,
                    &crate::arch::presets::sg2042().sockets[0],
                ),
                Some(other) => {
                    return Err(spec_err(format!(
                        "`host_overhead` must be a finite number or \"auto\", got `{other}`"
                    )));
                }
                None => v.as_float().filter(|f| f.is_finite()).ok_or_else(|| {
                    spec_err("`host_overhead` must be a finite number or \"auto\"".into())
                })?,
            };
        }
        if let Some(v) = sec.get("native_rvv10") {
            k.native_rvv10 =
                v.as_bool().ok_or_else(|| spec_err("`native_rvv10` must be a bool".into()))?;
        }
        match (sec.get("source"), sec.get("path")) {
            (Some(_), Some(_)) => {
                return Err(spec_err("`source` and `path` are mutually exclusive".into()));
            }
            (Some(v), None) => {
                let text =
                    v.as_str().ok_or_else(|| spec_err("`source` must be a string".into()))?;
                if k.family != KernelFamily::AsmSource {
                    return Err(spec_err("`source` requires family = \"asm-source\"".into()));
                }
                k.asm = Some(Arc::new(AsmSource::assemble(text, "<spec>")?));
            }
            (None, Some(v)) => {
                let rel = v.as_str().ok_or_else(|| spec_err("`path` must be a string".into()))?;
                if k.family != KernelFamily::AsmSource {
                    return Err(spec_err("`path` requires family = \"asm-source\"".into()));
                }
                let full = match dir {
                    Some(d) => d.join(rel),
                    None => Path::new(rel).to_path_buf(),
                };
                let text = std::fs::read_to_string(&full).map_err(|e| {
                    spec_err(format!("cannot read listing `{}`: {e}", full.display()))
                })?;
                k.asm = Some(Arc::new(AsmSource::assemble(&text, rel)?));
            }
            (None, None) => {
                // family switched to asm-source without a listing (and
                // the base didn't carry one): reject before validate()
                // does, with the spec-level fix spelled out
                if k.family == KernelFamily::AsmSource && k.asm.is_none() {
                    return Err(spec_err(
                        "family = \"asm-source\" needs `source = '''...'''` or `path = \"...\"`"
                            .into(),
                    ));
                }
                // family switched *away* from asm-source: drop the
                // inherited listing rather than tripping the coherence
                // guard in validate()
                if k.family != KernelFamily::AsmSource {
                    k.asm = None;
                }
            }
        }
        self.register(k)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builtin_kernels_register_and_resolve_aliases() {
        let reg = KernelRegistry::builtin();
        assert_eq!(
            reg.ids(),
            [
                "blis-lmul1",
                "blis-lmul4",
                "blis-rvv1-lmul2",
                "blis-rvv1-lmul4",
                "blis-rvv1-vl256",
                "openblas-c920",
                "openblas-generic",
            ]
        );
        // the seed's `UkernelId::parse` spellings all still resolve
        assert_eq!(reg.get("openblas").unwrap().id, "openblas-c920");
        assert_eq!(reg.get("openblas-opt").unwrap().id, "openblas-c920");
        assert_eq!(reg.get("generic").unwrap().id, "openblas-generic");
        assert_eq!(reg.get("blis").unwrap().id, "blis-lmul1");
        assert_eq!(reg.get("blis-vanilla").unwrap().id, "blis-lmul1");
        assert_eq!(reg.get("blis-opt").unwrap().id, "blis-lmul4");
        assert_eq!(reg.get("blis-rvv1").unwrap().id, "blis-rvv1-lmul2");
        assert_eq!(reg.get("blis-c930").unwrap().id, "blis-rvv1-vl256");
    }

    #[test]
    fn vl256_kernel_register_allocates_and_rejects_wider_tiles() {
        // 16x4 at VLEN=256 / LMUL=4: one 16-double group per column run
        // (accumulators v0..v15, A at v16..v19) — doubling nr pushes the
        // accumulator file past v31, the LMUL=8-style overflow rejection
        let k = blis_rvv1_vl256();
        k.validate().unwrap();
        let mut too_wide = k.clone();
        too_wide.nr = 8;
        assert!(matches!(too_wide.validate(), Err(CimoneError::InvalidKernel { .. })));
    }

    #[test]
    fn auto_host_overhead_calibrates_from_the_cache_simulator() {
        use crate::util::config::Config;
        let cfg = Config::parse(
            "[[kernel]]\nid = \"blis-auto\"\nbase = \"blis-lmul4\"\nhost_overhead = \"auto\"\n",
        )
        .unwrap();
        let mut reg = KernelRegistry::builtin();
        let k = reg.register_section(&cfg.table_arrays["kernel"][0]).unwrap();
        // the calibration formula's floor/ceiling, and determinism: the
        // value is exactly what the analysis-layer calibration returns
        assert!((0.10..=0.45).contains(&k.host_overhead), "{}", k.host_overhead);
        let want = super::super::analysis::calibrated_host_overhead(
            &k,
            &crate::arch::presets::sg2042().sockets[0],
        );
        assert_eq!(k.host_overhead.to_bits(), want.to_bits());
        // junk strings stay typed errors
        let cfg = Config::parse(
            "[[kernel]]\nid = \"dud\"\nbase = \"blis-lmul4\"\nhost_overhead = \"manual\"\n",
        )
        .unwrap();
        match reg.register_section(&cfg.table_arrays["kernel"][0]) {
            Err(CimoneError::Spec(m)) => assert!(m.contains("\"auto\""), "{m}"),
            other => panic!("expected Spec error, got {other:?}"),
        }
    }

    #[test]
    fn unknown_kernel_is_typed_and_lists_known_ids() {
        let reg = KernelRegistry::builtin();
        match reg.get("mkl") {
            Err(CimoneError::UnknownKernel { name, known }) => {
                assert_eq!(name, "mkl");
                assert!(known.contains("blis-lmul4"), "{known}");
            }
            other => panic!("expected UnknownKernel, got {other:?}"),
        }
    }

    #[test]
    fn duplicate_id_and_alias_rejected() {
        let mut reg = KernelRegistry::builtin();
        assert!(matches!(reg.register(blis_lmul4()), Err(CimoneError::DuplicateKernel(_))));
        let mut k = blis_lmul4();
        k.id = "blis-b".into();
        k.aliases = vec!["openblas".into()]; // clashes with openblas-c920's alias
        assert!(matches!(reg.register(k), Err(CimoneError::DuplicateKernel(_))));
    }

    #[test]
    fn validation_catches_broken_invariants() {
        let breakers: [fn(&mut KernelDescriptor); 7] = [
            |k| k.vlen_bits = 100,            // not a power of two
            |k| k.vlen_bits = 1 << 40,        // past the architectural max
            |k| k.lmul = Lmul::M8,            // 8x4 at M8 overflows the file
            |k| k.mr = 0,                     // empty tile
            |k| k.k_unroll = 0,               // zero unroll
            |k| k.host_overhead = 1.0,        // outside [0, 1)
            |k| k.id = "has space".into(),    // malformed id
        ];
        for broken in breakers {
            let mut k = blis_lmul4();
            broken(&mut k);
            assert!(matches!(k.validate(), Err(CimoneError::InvalidKernel { .. })), "{k:?}");
        }
        // a scalar tile too big for f16..f31
        let mut k = openblas_generic();
        k.mr = 8;
        k.nr = 4;
        assert!(matches!(k.validate(), Err(CimoneError::InvalidKernel { .. })));
        // scalar is an openblas-asm-only configuration
        let mut k = blis_lmul1();
        k.vlen_bits = 0;
        assert!(matches!(k.validate(), Err(CimoneError::InvalidKernel { .. })));
    }

    #[test]
    fn any_power_of_two_vlen_validates() {
        for vlen in [64usize, 128, 256, 512, 1024] {
            let mut k = blis_lmul4();
            k.id = format!("blis-v{vlen}");
            k.aliases = Vec::new();
            k.vlen_bits = vlen;
            // at VLEN=64 the 8x4 M4 tile needs 2 groups/column: 4 cols x
            // 8 regs + the A groups overflow — that's a *typed* error
            let v = k.validate();
            if vlen == 64 {
                assert!(matches!(v, Err(CimoneError::InvalidKernel { .. })));
            } else {
                assert!(v.is_ok(), "VLEN {vlen}: {v:?}");
            }
        }
    }

    #[test]
    fn all_builtins_run_c_plus_ab() {
        let reg = KernelRegistry::builtin();
        for k in reg.kernels() {
            let (mr, nr) = k.tile();
            assert!((0.0..1.0).contains(&k.host_overhead), "{}", k.id);
            let a = Matrix::random_hpl(mr, 16, 1);
            let b = Matrix::random_hpl(16, nr, 2);
            let c = Matrix::random_hpl(mr, nr, 3);
            let out = k.run(&a, &b, &c).unwrap();
            let mut want = c.clone();
            Matrix::gemm_acc(&mut want, &a, &b);
            assert!(out.allclose(&want, 1e-13, 1e-13), "{}", k.id);
        }
    }

    #[test]
    fn native_rvv1_kernels_compute_identically_to_the_retrofits() {
        // tuning changes the schedule, never the math: all four BLIS
        // kernels round identically (same rank-1 order)
        let reg = KernelRegistry::builtin();
        let a = Matrix::random_hpl(8, 32, 21);
        let b = Matrix::random_hpl(32, 4, 22);
        let c = Matrix::random_hpl(8, 4, 23);
        let want = reg.get("blis-lmul1").unwrap().run(&a, &b, &c).unwrap();
        for id in ["blis-lmul4", "blis-rvv1-lmul2", "blis-rvv1-lmul4"] {
            let out = reg.get(id).unwrap().run(&a, &b, &c).unwrap();
            assert!(out.allclose(&want, 0.0, 0.0), "{id}: schedules must round identically");
        }
    }

    #[test]
    fn custom_kernel_from_section_inherits_and_overrides() {
        use crate::util::config::Config;
        let cfg = Config::parse(
            "[[kernel]]\nid = \"blis-u8\"\nbase = \"blis-rvv1-lmul2\"\nk_unroll = 8\nhost_overhead = 0.15\n",
        )
        .unwrap();
        let mut reg = KernelRegistry::builtin();
        let k = reg.register_section(&cfg.table_arrays["kernel"][0]).unwrap();
        assert_eq!(k.id, "blis-u8");
        assert_eq!(k.k_unroll, 8);
        assert!((k.host_overhead - 0.15).abs() < 1e-12);
        // inherited geometry and dialect tuning
        assert_eq!((k.vlen_bits, k.lmul, k.mr, k.nr), (128, Lmul::M2, 8, 4));
        assert!(k.native_rvv10, "inherited from the native base");
        assert_eq!(reg.get("blis-u8").unwrap().id, "blis-u8");
        // ...and the dialect flag is overridable (a 0.7.1 re-port of a
        // native kernel), so PORT_TAX follows the spec, not the base
        let cfg = Config::parse(
            "[[kernel]]\nid = \"blis-u8-071\"\nbase = \"blis-u8\"\nnative_rvv10 = false\n",
        )
        .unwrap();
        let k = reg.register_section(&cfg.table_arrays["kernel"][0]).unwrap();
        assert!(!k.native_rvv10);
    }

    #[test]
    fn custom_kernel_unknown_key_is_rejected() {
        use crate::util::config::Config;
        let cfg =
            Config::parse("[[kernel]]\nid = \"typo\"\nbase = \"blis-lmul4\"\nk_unrol = 4\n")
                .unwrap();
        let mut reg = KernelRegistry::builtin();
        match reg.register_section(&cfg.table_arrays["kernel"][0]) {
            Err(CimoneError::Spec(m)) => assert!(m.contains("unknown key `k_unrol`"), "{m}"),
            other => panic!("expected Spec error, got {other:?}"),
        }
    }

    #[test]
    fn custom_kernel_bad_override_is_rejected() {
        use crate::util::config::Config;
        // lmul = 8 on the 8x4 tile cannot be register-allocated
        let cfg = Config::parse("[[kernel]]\nid = \"dud\"\nbase = \"blis-lmul4\"\nlmul = 8\n")
            .unwrap();
        let mut reg = KernelRegistry::builtin();
        assert!(matches!(
            reg.register_section(&cfg.table_arrays["kernel"][0]),
            Err(CimoneError::InvalidKernel { .. })
        ));
    }

    #[test]
    fn e32_kernel_validates_and_shifts_the_content_hash() {
        let mut k = blis_lmul4();
        k.id = "blis-lmul4-e32".into();
        k.aliases = Vec::new();
        k.sew = Sew::E32;
        k.validate().unwrap();
        // element width is a real tunable: it must move the cache key
        assert_ne!(k.content_hash(), blis_lmul4().content_hash());
        // the doubled-MR MxP tile is also allocatable (same register
        // budget as the E64 original)
        k.mr = 16;
        k.validate().unwrap();
    }

    #[test]
    fn e32_on_a_scalar_kernel_is_a_typed_error() {
        let mut k = openblas_generic();
        k.sew = Sew::E32;
        match k.validate() {
            Err(CimoneError::InvalidKernel { reason, .. }) => {
                assert!(reason.contains("FP64-only"), "{reason}")
            }
            other => panic!("expected InvalidKernel, got {other:?}"),
        }
    }

    #[test]
    fn custom_kernel_sew_override_parses_and_rejects_junk() {
        use crate::util::config::Config;
        let cfg = Config::parse(
            "[[kernel]]\nid = \"blis-sp\"\nbase = \"blis-lmul4\"\nsew = 32\nmr = 16\n",
        )
        .unwrap();
        let mut reg = KernelRegistry::builtin();
        let k = reg.register_section(&cfg.table_arrays["kernel"][0]).unwrap();
        assert_eq!(k.sew, Sew::E32);
        assert_eq!(k.mr, 16);
        // only the two hardware widths exist
        let cfg =
            Config::parse("[[kernel]]\nid = \"dud\"\nbase = \"blis-lmul4\"\nsew = 16\n").unwrap();
        match reg.register_section(&cfg.table_arrays["kernel"][0]) {
            Err(CimoneError::Spec(m)) => assert!(m.contains("32 or 64"), "{m}"),
            other => panic!("expected Spec error, got {other:?}"),
        }
    }

    /// A complete 4x2 RVV 1.0 micro-kernel at VLEN=128 / LMUL=2 (one
    /// group = one C column), one k-step per loop iteration.
    const ASM_4X2: &str = "\
    vsetvli t0, 4, e64, m2, ta, ma
    vle64.v v0, 0(a2)
    vle64.v v2, 4(a2)
.loop:
    vle64.v v4, 0(a0)
    fld f0, 0(a1)
    vfmacc.vf v0, f0, v4
    fld f1, 1(a1)
    vfmacc.vf v2, f1, v4
    addi a0, a0, 32
    addi a1, a1, 16
    bnez t1, .loop
    vse64.v v0, 0(a2)
    vse64.v v2, 4(a2)
";

    fn asm_4x2_section(extra: &str) -> crate::util::config::Section {
        use crate::util::config::Config;
        let text = format!(
            "[[kernel]]\nid = \"asm-4x2\"\nbase = \"blis-rvv1-lmul2\"\n\
             family = \"asm-source\"\nmr = 4\nnr = 2\nk_unroll = 1\n{extra}\
             source = '''\n{ASM_4X2}'''\n"
        );
        Config::parse(&text).unwrap().table_arrays["kernel"][0].clone()
    }

    #[test]
    fn asm_source_kernel_registers_and_computes_c_plus_ab() {
        let mut reg = KernelRegistry::builtin();
        let k = reg.register_section(&asm_4x2_section("")).unwrap();
        assert_eq!(k.family, KernelFamily::AsmSource);
        assert!(k.asm.is_some());
        assert_eq!((k.mr, k.nr, k.k_unroll), (4, 2, 1));
        let a = Matrix::random_hpl(4, 16, 11);
        let b = Matrix::random_hpl(16, 2, 12);
        let c = Matrix::random_hpl(4, 2, 13);
        let out = k.run(&a, &b, &c).unwrap();
        let mut want = c.clone();
        Matrix::gemm_acc(&mut want, &a, &b);
        assert!(out.allclose(&want, 1e-13, 1e-13), "assembled kernel must compute C + A*B");
    }

    #[test]
    fn asm_source_family_needs_a_listing() {
        use crate::util::config::Config;
        let cfg = Config::parse(
            "[[kernel]]\nid = \"nolisting\"\nbase = \"blis-rvv1-lmul2\"\nfamily = \"asm-source\"\n",
        )
        .unwrap();
        let mut reg = KernelRegistry::builtin();
        match reg.register_section(&cfg.table_arrays["kernel"][0]) {
            Err(CimoneError::Spec(m)) => assert!(m.contains("needs `source"), "{m}"),
            other => panic!("expected Spec error, got {other:?}"),
        }
    }

    #[test]
    fn listing_on_generator_family_is_rejected() {
        use crate::util::config::Config;
        let cfg = Config::parse(
            "[[kernel]]\nid = \"mixed\"\nbase = \"blis-lmul4\"\nsource = '''\nbnez t1, .loop\n'''\n",
        )
        .unwrap();
        let mut reg = KernelRegistry::builtin();
        match reg.register_section(&cfg.table_arrays["kernel"][0]) {
            Err(CimoneError::Spec(m)) => {
                assert!(m.contains("requires family = \"asm-source\""), "{m}")
            }
            other => panic!("expected Spec error, got {other:?}"),
        }
    }

    #[test]
    fn source_and_path_are_mutually_exclusive() {
        use crate::util::config::Config;
        let cfg = Config::parse(
            "[[kernel]]\nid = \"both\"\nbase = \"blis-rvv1-lmul2\"\nfamily = \"asm-source\"\n\
             path = \"x.S\"\nsource = '''\nbnez t1, .loop\n'''\n",
        )
        .unwrap();
        let mut reg = KernelRegistry::builtin();
        match reg.register_section(&cfg.table_arrays["kernel"][0]) {
            Err(CimoneError::Spec(m)) => assert!(m.contains("mutually exclusive"), "{m}"),
            other => panic!("expected Spec error, got {other:?}"),
        }
    }

    #[test]
    fn declared_unroll_must_match_the_listing() {
        // k_unroll = 2 while the body only covers k-step 0: typed, with
        // the missing step named
        let mut sec = asm_4x2_section("");
        sec.insert("k_unroll".into(), crate::util::config::Value::Int(2));
        let mut reg = KernelRegistry::builtin();
        match reg.register_section(&sec) {
            Err(CimoneError::InvalidKernel { reason, .. }) => {
                assert!(reason.contains("k-step 1"), "{reason}")
            }
            other => panic!("expected InvalidKernel, got {other:?}"),
        }
    }

    #[test]
    fn content_hash_ignores_cosmetic_listing_edits() {
        let mut reg = KernelRegistry::builtin();
        let k = reg.register_section(&asm_4x2_section("")).unwrap();
        // comments, blank lines and label spelling don't feed the cache
        let cosmetic = format!("# cosmetic header\n\n{}", ASM_4X2.replace(".loop", ".kloop"));
        let mut k2 = (*k).clone();
        k2.asm = Some(Arc::new(AsmSource::assemble(&cosmetic, "other.S").unwrap()));
        assert_eq!(k.content_hash(), k2.content_hash());
        // a real edit (different avl) must change the key
        let edited = ASM_4X2.replace("vsetvli t0, 4", "vsetvli t0, 2");
        let mut k3 = (*k).clone();
        k3.asm = Some(Arc::new(AsmSource::assemble(&edited, "edited.S").unwrap()));
        assert_ne!(k.content_hash(), k3.content_hash());
    }
}
