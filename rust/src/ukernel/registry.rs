//! Micro-kernel trait + registry.

use super::layout::PanelLayout;
use crate::error::CimoneError;
use crate::isa::exec::VecMachine;
use crate::isa::inst::Program;
use crate::util::Matrix;

/// Identifier for the four kernels of the paper's evaluation.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum UkernelId {
    OpenblasGeneric,
    OpenblasC920,
    BlisLmul1,
    BlisLmul4,
}

impl UkernelId {
    pub fn all() -> [UkernelId; 4] {
        [
            UkernelId::OpenblasGeneric,
            UkernelId::OpenblasC920,
            UkernelId::BlisLmul1,
            UkernelId::BlisLmul4,
        ]
    }

    pub fn label(&self) -> &'static str {
        match self {
            UkernelId::OpenblasGeneric => "OpenBLAS (generic RV64)",
            UkernelId::OpenblasC920 => "OpenBLAS (C920-optimized)",
            UkernelId::BlisLmul1 => "BLIS (vanilla RVV, LMUL=1)",
            UkernelId::BlisLmul4 => "BLIS (optimized, LMUL=4)",
        }
    }

    /// Canonical spec-file spelling; always re-parseable by
    /// [`UkernelId::parse`], so spec render/parse round-trips.
    pub fn spec_name(&self) -> &'static str {
        match self {
            UkernelId::OpenblasGeneric => "openblas-generic",
            UkernelId::OpenblasC920 => "openblas-c920",
            UkernelId::BlisLmul1 => "blis-lmul1",
            UkernelId::BlisLmul4 => "blis-lmul4",
        }
    }

    pub fn parse(s: &str) -> Option<UkernelId> {
        match s {
            "openblas-generic" | "generic" => Some(UkernelId::OpenblasGeneric),
            "openblas" | "openblas-opt" | "openblas-c920" => Some(UkernelId::OpenblasC920),
            "blis" | "blis-vanilla" | "blis-lmul1" => Some(UkernelId::BlisLmul1),
            "blis-opt" | "blis-lmul4" => Some(UkernelId::BlisLmul4),
            _ => None,
        }
    }

    pub fn build(&self) -> Box<dyn MicroKernel> {
        match self {
            UkernelId::OpenblasGeneric => Box::new(super::openblas_generic::OpenblasGeneric),
            UkernelId::OpenblasC920 => Box::new(super::openblas_c920::OpenblasC920),
            UkernelId::BlisLmul1 => Box::new(super::blis_lmul1::BlisLmul1),
            UkernelId::BlisLmul4 => Box::new(super::blis_lmul4::BlisLmul4),
        }
    }
}

/// A GEMM micro-kernel: generates an instruction schedule for C += A*B on
/// packed (MR x KC) x (KC x NR) panels.
pub trait MicroKernel {
    fn id(&self) -> UkernelId;

    /// Native register-tile geometry (mr, nr).
    fn tile(&self) -> (usize, usize);

    /// Emit the full micro-kernel program for KC rank-1 update steps.
    fn program(&self, layout: PanelLayout) -> Program;

    /// Fraction of end-to-end DGEMM time spent *outside* this kernel
    /// (packing, edge tiles, BLAS framework dispatch). Calibrated per
    /// library — see EXPERIMENTS.md 'Calibration'.
    fn host_overhead(&self) -> f64;

    /// Execute the kernel on real data via the functional machine.
    /// Returns the updated C tile.
    fn run(
        &self,
        a: &Matrix,
        b: &Matrix,
        c: &Matrix,
        vlen_bits: usize,
    ) -> Result<Matrix, CimoneError> {
        let (mr, nr) = self.tile();
        let layout = PanelLayout::new(mr, nr, a.cols());
        let prog = self.program(layout);
        let mut m = VecMachine::new(vlen_bits, layout.mem_words());
        m.mem = layout.pack(a, b, c);
        m.run(&prog).map_err(CimoneError::Machine)?;
        Ok(layout.unpack_c(&m.mem))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_roundtrip() {
        assert_eq!(UkernelId::parse("blis-opt"), Some(UkernelId::BlisLmul4));
        assert_eq!(UkernelId::parse("openblas"), Some(UkernelId::OpenblasC920));
        assert_eq!(UkernelId::parse("generic"), Some(UkernelId::OpenblasGeneric));
        assert_eq!(UkernelId::parse("mkl"), None);
    }

    #[test]
    fn spec_name_reparses_to_the_same_id() {
        for id in UkernelId::all() {
            assert_eq!(UkernelId::parse(id.spec_name()), Some(id));
        }
    }

    #[test]
    fn all_build() {
        for id in UkernelId::all() {
            let k = id.build();
            assert_eq!(k.id(), id);
            let (mr, nr) = k.tile();
            assert!(mr > 0 && nr > 0);
            assert!((0.0..1.0).contains(&k.host_overhead()));
        }
    }
}
