//! In-house micro-benchmark harness (criterion is unavailable offline).
//!
//! Used by every target in `rust/benches/` (all declared `harness = false`)
//! and by the §Perf optimization loop. Methodology: warmup runs, then N
//! timed samples of K iterations each; reports median ± spread so one-off
//! scheduler hiccups don't skew the comparison.

use std::time::Instant;

use crate::util::stats::Summary;

/// One benchmark measurement.
#[derive(Debug, Clone)]
pub struct Measurement {
    pub name: String,
    /// Seconds per iteration (median across samples).
    pub secs_per_iter: f64,
    pub summary: Summary,
    pub iters_per_sample: u64,
}

impl Measurement {
    /// Derived throughput given work-per-iteration.
    pub fn throughput(&self, units_per_iter: f64) -> f64 {
        units_per_iter / self.secs_per_iter
    }

    pub fn report(&self) -> String {
        format!(
            "{:<44} {:>12}/iter  (n={}, cv={:.1}%)",
            self.name,
            crate::util::units::fmt_secs(self.secs_per_iter),
            self.summary.n,
            self.summary.cv() * 100.0
        )
    }
}

/// Benchmark runner with tunable sampling.
pub struct Bench {
    pub warmup_iters: u64,
    pub samples: usize,
    pub min_sample_secs: f64,
}

impl Default for Bench {
    fn default() -> Self {
        Bench { warmup_iters: 3, samples: 10, min_sample_secs: 0.05 }
    }
}

impl Bench {
    /// Quick preset for expensive end-to-end benches.
    pub fn quick() -> Self {
        Bench { warmup_iters: 1, samples: 3, min_sample_secs: 0.01 }
    }

    /// Time `f`, auto-calibrating iterations per sample so each sample
    /// runs at least `min_sample_secs`.
    pub fn run<F: FnMut()>(&self, name: &str, mut f: F) -> Measurement {
        for _ in 0..self.warmup_iters {
            f();
        }
        // calibrate
        let mut iters: u64 = 1;
        loop {
            let t = Instant::now();
            for _ in 0..iters {
                f();
            }
            let el = t.elapsed().as_secs_f64();
            if el >= self.min_sample_secs || iters >= 1 << 20 {
                break;
            }
            let scale = (self.min_sample_secs / el.max(1e-9)).ceil() as u64;
            iters = (iters * scale.clamp(2, 100)).min(1 << 20);
        }
        let mut samples = Vec::with_capacity(self.samples);
        for _ in 0..self.samples {
            let t = Instant::now();
            for _ in 0..iters {
                f();
            }
            samples.push(t.elapsed().as_secs_f64() / iters as f64);
        }
        let summary = Summary::of(&samples);
        Measurement {
            name: name.to_string(),
            secs_per_iter: summary.median,
            summary,
            iters_per_sample: iters,
        }
    }
}

/// Prevent the optimizer from discarding a computed value (std::hint's
/// black_box is stable since 1.66; thin wrapper for uniformity).
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something_positive() {
        let b = Bench { warmup_iters: 1, samples: 3, min_sample_secs: 0.001 };
        let mut acc = 0u64;
        let m = b.run("spin", || {
            for i in 0..1000 {
                acc = acc.wrapping_add(black_box(i));
            }
        });
        assert!(m.secs_per_iter > 0.0);
        assert!(m.iters_per_sample >= 1);
    }

    #[test]
    fn throughput_inverts_time() {
        let m = Measurement {
            name: "x".into(),
            secs_per_iter: 0.5,
            summary: Summary::of(&[0.5]),
            iters_per_sample: 1,
        };
        assert!((m.throughput(10.0) - 20.0).abs() < 1e-12);
    }

    #[test]
    fn ordering_detects_slower_code() {
        let b = Bench { warmup_iters: 1, samples: 3, min_sample_secs: 0.002 };
        let fast = b.run("fast", || {
            black_box((0..100u64).sum::<u64>());
        });
        let slow = b.run("slow", || {
            black_box((0..20_000u64).sum::<u64>());
        });
        assert!(slow.secs_per_iter > fast.secs_per_iter);
    }
}
