//! Minimal CLI argument parser (clap is unavailable offline).
//!
//! Grammar: `cimone <subcommand> [--flag] [--key value] [positional...]`.

use std::collections::BTreeMap;

use crate::error::CimoneError;

/// Parsed command line.
#[derive(Debug, Clone, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    pub positional: Vec<String>,
    options: BTreeMap<String, String>,
    flags: Vec<String>,
}

impl Args {
    /// Parse from an iterator of raw args (without argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(raw: I) -> Result<Args, CimoneError> {
        let mut out = Args::default();
        let mut it = raw.into_iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(name) = tok.strip_prefix("--") {
                if name.is_empty() {
                    return Err(CimoneError::Cli("bare `--` not supported".into()));
                }
                if let Some((k, v)) = name.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    let v = it.next().unwrap();
                    out.options.insert(name.to_string(), v);
                } else {
                    out.flags.push(name.to_string());
                }
            } else if out.subcommand.is_none() {
                out.subcommand = Some(tok);
            } else {
                out.positional.push(tok);
            }
        }
        Ok(out)
    }

    /// Parse the process arguments.
    pub fn from_env() -> Result<Args, CimoneError> {
        Args::parse(std::env::args().skip(1))
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn get_usize(&self, name: &str, default: usize) -> Result<usize, CimoneError> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| CimoneError::Cli(format!("--{name}: expected integer, got `{v}`"))),
        }
    }

    pub fn get_f64(&self, name: &str, default: f64) -> Result<f64, CimoneError> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| CimoneError::Cli(format!("--{name}: expected float, got `{v}`"))),
        }
    }

    /// Comma-separated usize list (e.g. `--cores 1,8,16,32,64`).
    pub fn get_usize_list(
        &self,
        name: &str,
        default: &[usize],
    ) -> Result<Vec<usize>, CimoneError> {
        match self.get(name) {
            None => Ok(default.to_vec()),
            Some(v) => v
                .split(',')
                .map(|t| {
                    t.trim()
                        .parse()
                        .map_err(|_| CimoneError::Cli(format!("--{name}: bad entry `{t}`")))
                })
                .collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(toks: &[&str]) -> Args {
        Args::parse(toks.iter().map(|s| s.to_string())).unwrap()
    }

    #[test]
    fn subcommand_and_positional() {
        let a = parse(&["hpl", "extra"]);
        assert_eq!(a.subcommand.as_deref(), Some("hpl"));
        assert_eq!(a.positional, vec!["extra"]);
    }

    #[test]
    fn options_space_and_equals() {
        let a = parse(&["hpl", "--cores", "64", "--lib=blis-opt"]);
        assert_eq!(a.get("cores"), Some("64"));
        assert_eq!(a.get("lib"), Some("blis-opt"));
    }

    #[test]
    fn trailing_flag() {
        let a = parse(&["stream", "--pjrt"]);
        assert!(a.flag("pjrt"));
        assert!(!a.flag("other"));
    }

    #[test]
    fn flag_followed_by_flag() {
        let a = parse(&["x", "--verbose", "--dry-run"]);
        assert!(a.flag("verbose") && a.flag("dry-run"));
    }

    #[test]
    fn typed_getters() {
        let a = parse(&["x", "--n", "100", "--f", "2.5", "--cores", "1,2,4"]);
        assert_eq!(a.get_usize("n", 0).unwrap(), 100);
        assert_eq!(a.get_usize("missing", 7).unwrap(), 7);
        assert!((a.get_f64("f", 0.0).unwrap() - 2.5).abs() < 1e-12);
        assert_eq!(a.get_usize_list("cores", &[]).unwrap(), vec![1, 2, 4]);
    }

    #[test]
    fn bad_int_is_error() {
        let a = parse(&["x", "--n", "abc"]);
        assert!(a.get_usize("n", 0).is_err());
    }
}
