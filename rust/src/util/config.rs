//! TOML-subset configuration parser (serde/toml are unavailable offline).
//!
//! Supports the subset our cluster/experiment configs need:
//! `[section]` headers, `key = value` with string/int/float/bool values,
//! flat arrays of those, `#` comments, `[[section]]` table arrays
//! (used for node inventories), and `key = '''` multi-line literal
//! strings (used for inline assembly listings in `[[kernel]]` sections
//! — the body is taken verbatim, `#` included, until a line holding
//! only `'''`).

use std::collections::BTreeMap;

/// A parsed scalar or array value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Array(Vec<Value>),
}

impl Value {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_int(&self) -> Option<i64> {
        match self {
            Value::Int(i) => Some(*i),
            _ => None,
        }
    }
    pub fn as_float(&self) -> Option<f64> {
        match self {
            Value::Float(f) => Some(*f),
            Value::Int(i) => Some(*i as f64),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }
}

/// One `[section]`: ordered key/value map.
pub type Section = BTreeMap<String, Value>;

/// Parsed config: named sections plus repeated `[[name]]` table arrays.
#[derive(Debug, Default, Clone)]
pub struct Config {
    pub sections: BTreeMap<String, Section>,
    pub table_arrays: BTreeMap<String, Vec<Section>>,
    /// The file this config was loaded from ([`Config::load`] sets it;
    /// in-memory parses leave `None`). Relative paths inside the config
    /// — e.g. a `[[kernel]]` `path = "..."` listing — resolve against
    /// this file's directory.
    pub origin: Option<String>,
}

impl Config {
    /// Parse from text; line-based, returns the first error with its line.
    pub fn parse(text: &str) -> Result<Config, String> {
        let mut cfg = Config::default();
        // current destination: (is_array, name)
        let mut cur: Option<(bool, String)> = None;
        let lines: Vec<&str> = text.lines().collect();
        let mut i = 0usize;
        while i < lines.len() {
            let lineno = i + 1;
            let line = strip_comment(lines[i]).trim().to_string();
            i += 1;
            if line.is_empty() {
                continue;
            }
            if let Some(name) = line.strip_prefix("[[").and_then(|s| s.strip_suffix("]]")) {
                let name = name.trim().to_string();
                cfg.table_arrays.entry(name.clone()).or_default().push(Section::new());
                cur = Some((true, name));
            } else if let Some(name) = line.strip_prefix('[').and_then(|s| s.strip_suffix(']')) {
                let name = name.trim().to_string();
                cfg.sections.entry(name.clone()).or_default();
                cur = Some((false, name));
            } else if let Some((k, v)) = line.split_once('=') {
                let key = k.trim().to_string();
                let val = if v.trim() == "'''" {
                    // multi-line literal string: raw lines, verbatim
                    // (no comment stripping — `#` is asm syntax), up to
                    // a line holding only `'''`
                    let mut body = Vec::new();
                    loop {
                        match lines.get(i) {
                            None => {
                                return Err(format!(
                                    "line {lineno}: unterminated `'''` string (no closing `'''`)"
                                ));
                            }
                            Some(l) if l.trim() == "'''" => {
                                i += 1;
                                break;
                            }
                            Some(l) => {
                                body.push(*l);
                                i += 1;
                            }
                        }
                    }
                    let mut s = body.join("\n");
                    s.push('\n');
                    Value::Str(s)
                } else {
                    parse_value(v.trim()).map_err(|e| format!("line {lineno}: {e}"))?
                };
                let dest = match &cur {
                    Some((true, name)) => {
                        cfg.table_arrays.get_mut(name).unwrap().last_mut().unwrap()
                    }
                    Some((false, name)) => cfg.sections.get_mut(name).unwrap(),
                    None => cfg.sections.entry(String::new()).or_default(),
                };
                dest.insert(key, val);
            } else {
                return Err(format!("line {lineno}: unparseable `{line}`"));
            }
        }
        Ok(cfg)
    }

    /// Load from a file path. Records the path as [`Config::origin`] so
    /// relative paths inside the config can resolve against it.
    pub fn load(path: &str) -> Result<Config, String> {
        let text = std::fs::read_to_string(path).map_err(|e| format!("{path}: {e}"))?;
        let mut cfg = Config::parse(&text)?;
        cfg.origin = Some(path.to_string());
        Ok(cfg)
    }

    pub fn section(&self, name: &str) -> Option<&Section> {
        self.sections.get(name)
    }

    /// Typed lookup with a dotted path `section.key`.
    pub fn get(&self, path: &str) -> Option<&Value> {
        let (sec, key) = path.split_once('.')?;
        self.sections.get(sec)?.get(key)
    }
}

fn strip_comment(line: &str) -> &str {
    // naive but sufficient: `#` outside quotes ends the line
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(tok: &str) -> Result<Value, String> {
    if tok.is_empty() {
        return Err("empty value".into());
    }
    if let Some(inner) = tok.strip_prefix('"').and_then(|s| s.strip_suffix('"')) {
        return Ok(Value::Str(inner.to_string()));
    }
    if tok == "true" {
        return Ok(Value::Bool(true));
    }
    if tok == "false" {
        return Ok(Value::Bool(false));
    }
    if let Some(inner) = tok.strip_prefix('[').and_then(|s| s.strip_suffix(']')) {
        let inner = inner.trim();
        if inner.is_empty() {
            return Ok(Value::Array(vec![]));
        }
        let items: Result<Vec<Value>, String> =
            inner.split(',').map(|t| parse_value(t.trim())).collect();
        return Ok(Value::Array(items?));
    }
    if let Ok(i) = tok.parse::<i64>() {
        return Ok(Value::Int(i));
    }
    if let Ok(f) = tok.parse::<f64>() {
        return Ok(Value::Float(f));
    }
    Err(format!("cannot parse value `{tok}`"))
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
# cluster definition
[cluster]
name = "monte-cimone"
nodes = 12
eth_gbps = 1.0
monitoring = true
core_counts = [1, 8, 16]

[[node]]
name = "mcv1-01"
soc = "u740"

[[node]]
name = "mcv2-01"
soc = "sg2042"
sockets = 2
"#;

    #[test]
    fn parses_sections_and_types() {
        let c = Config::parse(SAMPLE).unwrap();
        assert_eq!(c.get("cluster.name").unwrap().as_str(), Some("monte-cimone"));
        assert_eq!(c.get("cluster.nodes").unwrap().as_int(), Some(12));
        assert_eq!(c.get("cluster.eth_gbps").unwrap().as_float(), Some(1.0));
        assert_eq!(c.get("cluster.monitoring").unwrap().as_bool(), Some(true));
    }

    #[test]
    fn parses_arrays() {
        let c = Config::parse(SAMPLE).unwrap();
        match c.get("cluster.core_counts").unwrap() {
            Value::Array(v) => {
                assert_eq!(v.len(), 3);
                assert_eq!(v[1].as_int(), Some(8));
            }
            other => panic!("expected array, got {other:?}"),
        }
    }

    #[test]
    fn parses_table_arrays() {
        let c = Config::parse(SAMPLE).unwrap();
        let nodes = &c.table_arrays["node"];
        assert_eq!(nodes.len(), 2);
        assert_eq!(nodes[0]["soc"].as_str(), Some("u740"));
        assert_eq!(nodes[1]["sockets"].as_int(), Some(2));
    }

    #[test]
    fn comments_stripped_but_not_in_strings() {
        let c = Config::parse("[s]\nk = \"a#b\" # trailing\n").unwrap();
        assert_eq!(c.get("s.k").unwrap().as_str(), Some("a#b"));
    }

    #[test]
    fn int_vs_float_distinction() {
        let c = Config::parse("[s]\ni = 3\nf = 3.5\n").unwrap();
        assert_eq!(c.get("s.i").unwrap().as_int(), Some(3));
        assert_eq!(c.get("s.i").unwrap().as_float(), Some(3.0)); // int coerces
        assert_eq!(c.get("s.f").unwrap().as_float(), Some(3.5));
        assert_eq!(c.get("s.f").unwrap().as_int(), None);
    }

    #[test]
    fn error_reports_line() {
        let err = Config::parse("[s]\nnot a kv\n").unwrap_err();
        assert!(err.contains("line 2"), "{err}");
    }

    #[test]
    fn empty_array() {
        let c = Config::parse("[s]\na = []\n").unwrap();
        assert_eq!(c.get("s.a").unwrap(), &Value::Array(vec![]));
    }

    #[test]
    fn multiline_string_is_verbatim() {
        // the body keeps `#` (asm comments) and indentation untouched,
        // and parsing resumes cleanly after the closing fence
        let text = "[s]\nsrc = '''\n  fld f0, 0(a1)  # load B\n'''\nafter = 1\n";
        let c = Config::parse(text).unwrap();
        assert_eq!(c.get("s.src").unwrap().as_str(), Some("  fld f0, 0(a1)  # load B\n"));
        assert_eq!(c.get("s.after").unwrap().as_int(), Some(1));
    }

    #[test]
    fn multiline_string_spans_section_like_lines() {
        let c = Config::parse("[s]\nsrc = '''\n[not a section]\n'''\n").unwrap();
        assert_eq!(c.get("s.src").unwrap().as_str(), Some("[not a section]\n"));
        assert!(!c.sections.contains_key("not a section"));
    }

    #[test]
    fn unterminated_multiline_string_reports_opening_line() {
        let err = Config::parse("[s]\nsrc = '''\nbody\n").unwrap_err();
        assert!(err.contains("line 2") && err.contains("unterminated"), "{err}");
    }

    #[test]
    fn parse_leaves_origin_unset() {
        assert_eq!(Config::parse("[s]\nk = 1\n").unwrap().origin, None);
    }
}
