//! Canonical content hashing for the memoized estimation layer.
//!
//! The estimation hot path ([`crate::ukernel::analysis`], the workload
//! estimators) is pure: identical resolved inputs — kernel descriptor
//! tunables, platform geometry, fabric parameters, problem shape —
//! always produce bit-identical outputs. A content hash of those inputs
//! is therefore a sound memoization key. This module provides the
//! canonical byte feed: FNV-1a in 128 bits (native `u128` arithmetic,
//! no dependencies), with every scalar written in a fixed-width
//! little-endian encoding and strings length-prefixed so that adjacent
//! fields can never alias (`"ab" + "c"` hashes differently from
//! `"a" + "bc"`).
//!
//! The same hasher renders the *determinism fingerprint* recorded by
//! `cimone bench` ([`fingerprint`]): a 32-hex-digit digest of a report's
//! JSON export, pinned in `BENCH_6.json` and re-checked twice per CI run
//! so silent result drift fails the build.

/// FNV-1a 128-bit offset basis.
const FNV_OFFSET: u128 = 0x6c62272e07bb014262b821756295c58d;
/// FNV-1a 128-bit prime (2^88 + 2^8 + 0x3b).
const FNV_PRIME: u128 = 0x1000000000000000000013b;

/// Incremental FNV-1a 128-bit hasher over a canonical byte feed.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ContentHasher {
    state: u128,
}

impl Default for ContentHasher {
    fn default() -> Self {
        ContentHasher::new()
    }
}

impl ContentHasher {
    pub fn new() -> ContentHasher {
        ContentHasher { state: FNV_OFFSET }
    }

    pub fn write_bytes(&mut self, bytes: &[u8]) -> &mut Self {
        for &b in bytes {
            self.state ^= b as u128;
            self.state = self.state.wrapping_mul(FNV_PRIME);
        }
        self
    }

    pub fn write_u64(&mut self, v: u64) -> &mut Self {
        self.write_bytes(&v.to_le_bytes())
    }

    pub fn write_usize(&mut self, v: usize) -> &mut Self {
        self.write_u64(v as u64)
    }

    /// Bit-exact float feed (`to_bits`): -0.0 and 0.0 hash differently,
    /// which is the conservative direction for a memoization key.
    pub fn write_f64(&mut self, v: f64) -> &mut Self {
        self.write_u64(v.to_bits())
    }

    pub fn write_bool(&mut self, v: bool) -> &mut Self {
        self.write_bytes(&[v as u8])
    }

    /// Length-prefixed string feed — concatenation-ambiguity free.
    pub fn write_str(&mut self, s: &str) -> &mut Self {
        self.write_usize(s.len());
        self.write_bytes(s.as_bytes())
    }

    pub fn finish(&self) -> u128 {
        self.state
    }

    /// 32-hex-digit rendering of the digest.
    pub fn hex(&self) -> String {
        format!("{:032x}", self.state)
    }
}

/// Digest one text blob — the determinism-fingerprint entry point used
/// by `cimone bench` over rendered report JSON.
pub fn fingerprint(text: &str) -> String {
    let mut h = ContentHasher::new();
    h.write_bytes(text.as_bytes());
    h.hex()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_feed_is_the_offset_basis() {
        assert_eq!(ContentHasher::new().finish(), FNV_OFFSET);
        assert_eq!(ContentHasher::new().hex().len(), 32);
    }

    #[test]
    fn stable_across_reruns() {
        let mut a = ContentHasher::new();
        a.write_str("blis-lmul4").write_usize(128).write_f64(0.23).write_bool(true);
        let mut b = ContentHasher::new();
        b.write_str("blis-lmul4").write_usize(128).write_f64(0.23).write_bool(true);
        assert_eq!(a.finish(), b.finish());
        assert_eq!(fingerprint("report"), fingerprint("report"));
    }

    #[test]
    fn distinct_inputs_distinct_digests() {
        let one = fingerprint("lmul=1");
        let four = fingerprint("lmul=4");
        assert_ne!(one, four);
        let mut a = ContentHasher::new();
        a.write_usize(128);
        let mut b = ContentHasher::new();
        b.write_usize(256);
        assert_ne!(a.finish(), b.finish());
    }

    #[test]
    fn length_prefix_blocks_concat_aliasing() {
        let mut a = ContentHasher::new();
        a.write_str("ab").write_str("c");
        let mut b = ContentHasher::new();
        b.write_str("a").write_str("bc");
        assert_ne!(a.finish(), b.finish());
    }

    #[test]
    fn float_feed_is_bit_exact() {
        let mut pos = ContentHasher::new();
        pos.write_f64(0.0);
        let mut neg = ContentHasher::new();
        neg.write_f64(-0.0);
        assert_ne!(pos.finish(), neg.finish());
    }
}
