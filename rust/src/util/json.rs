//! Minimal JSON parser + writer (serde is unavailable offline). Supports
//! the full JSON grammar minus exotic number forms; used for
//! `artifacts/manifest.json` and the `cimone campaign --json` export.

use std::collections::BTreeMap;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Build an object from `(key, value)` pairs — the writer-side
    /// counterpart of [`Json::get`], used by every `--json` export.
    pub fn obj<K: Into<String>, I: IntoIterator<Item = (K, Json)>>(pairs: I) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.into(), v)).collect())
    }

    pub fn parse(text: &str) -> Result<Json, String> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(format!("trailing garbage at byte {}", p.i));
        }
        Ok(v)
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn idx(&self, i: usize) -> Option<&Json> {
        match self {
            Json::Arr(v) => v.get(i),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|f| f as usize)
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Serialize to compact JSON text. Non-finite numbers (which JSON
    /// cannot represent) render as `null`.
    pub fn render(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.is_finite() {
                    out.push_str(&format!("{n}"));
                } else {
                    out.push_str("null");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(items) => {
                out.push('[');
                for (i, v) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(map) => {
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(out, k);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && self.b[self.i].is_ascii_whitespace() {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected `{}` at byte {}", c as char, self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        self.ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(format!("unexpected {:?} at byte {}", other.map(|c| c as char), self.i)),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn string(&mut self) -> Result<String, String> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    let e = self.peek().ok_or("bad escape")?;
                    self.i += 1;
                    match e {
                        b'n' => out.push('\n'),
                        b't' => out.push('\t'),
                        b'r' => out.push('\r'),
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'u' => {
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])
                                .map_err(|_| "bad \\u")?;
                            let cp = u32::from_str_radix(hex, 16).map_err(|_| "bad \\u")?;
                            out.push(char::from_u32(cp).unwrap_or('\u{FFFD}'));
                            self.i += 4;
                        }
                        _ => return Err(format!("unknown escape \\{}", e as char)),
                    }
                }
                Some(c) => {
                    // copy raw UTF-8 bytes through
                    let start = self.i;
                    let len = utf8_len(c);
                    self.i += len;
                    out.push_str(
                        std::str::from_utf8(&self.b[start..self.i]).map_err(|_| "bad utf8")?,
                    );
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || c == b'.' || c == b'e' || c == b'E' || c == b'+' || c == b'-' {
                self.i += 1;
            } else {
                break;
            }
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| format!("bad number at byte {start}"))
    }

    fn array(&mut self) -> Result<Json, String> {
        self.eat(b'[')?;
        let mut out = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            out.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                _ => return Err(format!("bad array at byte {}", self.i)),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.eat(b'{')?;
        let mut out = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.ws();
            let key = self.string()?;
            self.ws();
            self.eat(b':')?;
            let val = self.value()?;
            out.insert(key, val);
            self.ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(out));
                }
                _ => return Err(format!("bad object at byte {}", self.i)),
            }
        }
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_shaped_document() {
        let doc = r#"{
            "format": 1,
            "nb": 32,
            "entries": [
                {"name": "gemm_256", "file": "gemm_256.hlo.txt",
                 "inputs": [{"shape": [256, 256], "dtype": "f64"}],
                 "outputs": [{"shape": [256, 256], "dtype": "f64"}]}
            ]
        }"#;
        let j = Json::parse(doc).unwrap();
        assert_eq!(j.get("nb").unwrap().as_usize(), Some(32));
        let e = j.get("entries").unwrap().idx(0).unwrap();
        assert_eq!(e.get("name").unwrap().as_str(), Some("gemm_256"));
        let shape = e.get("inputs").unwrap().idx(0).unwrap().get("shape").unwrap();
        assert_eq!(shape.idx(1).unwrap().as_usize(), Some(256));
    }

    #[test]
    fn scalars_and_escapes() {
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-2.5e3").unwrap(), Json::Num(-2500.0));
        assert_eq!(Json::parse(r#""a\nb\"c""#).unwrap(), Json::Str("a\nb\"c".into()));
        assert_eq!(Json::parse(r#""A""#).unwrap(), Json::Str("A".into()));
    }

    #[test]
    fn empty_containers() {
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(Json::parse("{}").unwrap(), Json::Obj(BTreeMap::new()));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("nulll").is_err());
    }

    #[test]
    fn unicode_passthrough() {
        assert_eq!(Json::parse("\"µkernel\"").unwrap(), Json::Str("µkernel".into()));
    }

    #[test]
    fn render_roundtrips_through_parse() {
        let doc = r#"{"a": [1, 2.5, -3], "b": {"nested": true}, "s": "x\n\"y\"", "n": null}"#;
        let j = Json::parse(doc).unwrap();
        let back = Json::parse(&j.render()).unwrap();
        assert_eq!(j, back);
    }

    #[test]
    fn obj_builder_matches_hand_built_map() {
        let j = Json::obj([("b", Json::Num(1.0)), ("a", Json::Bool(true))]);
        assert_eq!(j.get("a"), Some(&Json::Bool(true)));
        assert_eq!(j.get("b"), Some(&Json::Num(1.0)));
        // BTreeMap ordering: keys render sorted regardless of insert order
        assert_eq!(j.render(), r#"{"a":true,"b":1}"#);
    }

    #[test]
    fn render_nonfinite_as_null() {
        assert_eq!(Json::Num(f64::NAN).render(), "null");
        assert_eq!(Json::Num(f64::INFINITY).render(), "null");
        assert_eq!(Json::Num(139.4).render(), "139.4");
    }
}
