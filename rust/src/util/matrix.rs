//! Column-major dense `f64` matrix — the in-memory format of HPL/BLAS.
//!
//! Column-major because the paper's whole pipeline (HPL, OpenBLAS, BLIS)
//! is Fortran-layout; keeping the same layout means our address-trace
//! generator (cache::trace) walks memory in exactly the order the real
//! libraries do.

use crate::util::rng::Rng;

/// Dense column-major matrix of f64.
#[derive(Debug, Clone, PartialEq)]
pub struct Matrix {
    rows: usize,
    cols: usize,
    /// Leading dimension (>= rows); data[i + j*ld].
    ld: usize,
    data: Vec<f64>,
}

impl Matrix {
    /// Zero matrix.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Matrix { rows, cols, ld: rows.max(1), data: vec![0.0; rows.max(1) * cols] }
    }

    /// Identity.
    pub fn eye(n: usize) -> Self {
        let mut m = Matrix::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    /// HPL-style random fill, uniform in [-0.5, 0.5).
    pub fn random_hpl(rows: usize, cols: usize, seed: u64) -> Self {
        let mut m = Matrix::zeros(rows, cols);
        let mut rng = Rng::new(seed);
        rng.fill_hpl(&mut m.data);
        m
    }

    /// Diagonally dominant random matrix (always nonsingular; what our
    /// LU tests factor when they want guaranteed stability).
    pub fn random_dd(n: usize, seed: u64) -> Self {
        let mut m = Matrix::random_hpl(n, n, seed);
        for i in 0..n {
            m[(i, i)] += n as f64;
        }
        m
    }

    /// Build from a row-major slice (test convenience).
    pub fn from_rows(rows: usize, cols: usize, vals: &[f64]) -> Self {
        assert_eq!(vals.len(), rows * cols);
        let mut m = Matrix::zeros(rows, cols);
        for i in 0..rows {
            for j in 0..cols {
                m[(i, j)] = vals[i * cols + j];
            }
        }
        m
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    pub fn ld(&self) -> usize {
        self.ld
    }

    /// Raw column-major storage.
    pub fn as_slice(&self) -> &[f64] {
        &self.data
    }

    pub fn as_mut_slice(&mut self) -> &mut [f64] {
        &mut self.data
    }

    /// Flatten to row-major (the layout the PJRT artifacts expect —
    /// jax arrays are row-major).
    pub fn to_row_major(&self) -> Vec<f64> {
        let mut out = Vec::with_capacity(self.rows * self.cols);
        for i in 0..self.rows {
            for j in 0..self.cols {
                out.push(self[(i, j)]);
            }
        }
        out
    }

    /// Inverse of [`to_row_major`].
    pub fn from_row_major(rows: usize, cols: usize, vals: &[f64]) -> Self {
        Self::from_rows(rows, cols, vals)
    }

    /// C += A * B, naive triple loop (jki order, column-major friendly).
    /// The reference semantics every optimized path is tested against.
    pub fn gemm_acc(c: &mut Matrix, a: &Matrix, b: &Matrix) {
        assert_eq!(a.cols, b.rows);
        assert_eq!(c.rows, a.rows);
        assert_eq!(c.cols, b.cols);
        for j in 0..b.cols {
            for k in 0..a.cols {
                let bkj = b[(k, j)];
                if bkj == 0.0 {
                    continue;
                }
                for i in 0..a.rows {
                    c[(i, j)] += a[(i, k)] * bkj;
                }
            }
        }
    }

    /// C -= A * B, slice-based inner loop (the HPL trailing-update hot
    /// path — no temporaries, auto-vectorizable i-loop over contiguous
    /// column storage).
    pub fn gemm_sub(c: &mut Matrix, a: &Matrix, b: &Matrix) {
        assert_eq!(a.cols, b.rows);
        assert_eq!(c.rows, a.rows);
        assert_eq!(c.cols, b.cols);
        let m = a.rows;
        let (ald, cld) = (a.ld, c.ld);
        for j in 0..b.cols {
            let ccol = &mut c.data[j * cld..j * cld + m];
            for k in 0..a.cols {
                let bkj = b[(k, j)];
                if bkj == 0.0 {
                    continue;
                }
                let acol = &a.data[k * ald..k * ald + m];
                for i in 0..m {
                    ccol[i] -= acol[i] * bkj;
                }
            }
        }
    }

    /// y = A * x.
    pub fn matvec(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(x.len(), self.cols);
        let mut y = vec![0.0; self.rows];
        for j in 0..self.cols {
            let xj = x[j];
            for i in 0..self.rows {
                y[i] += self[(i, j)] * xj;
            }
        }
        y
    }

    /// Copy a rectangular block into a new matrix.
    pub fn block(&self, i0: usize, j0: usize, rows: usize, cols: usize) -> Matrix {
        assert!(i0 + rows <= self.rows && j0 + cols <= self.cols);
        let mut m = Matrix::zeros(rows, cols);
        for j in 0..cols {
            for i in 0..rows {
                m[(i, j)] = self[(i0 + i, j0 + j)];
            }
        }
        m
    }

    /// Write a block back.
    pub fn set_block(&mut self, i0: usize, j0: usize, src: &Matrix) {
        assert!(i0 + src.rows <= self.rows && j0 + src.cols <= self.cols);
        for j in 0..src.cols {
            for i in 0..src.rows {
                self[(i0 + i, j0 + j)] = src[(i, j)];
            }
        }
    }

    /// Swap rows r1 and r2 over columns [j0, j1).
    pub fn swap_rows(&mut self, r1: usize, r2: usize, j0: usize, j1: usize) {
        if r1 == r2 {
            return;
        }
        for j in j0..j1 {
            let t = self[(r1, j)];
            self[(r1, j)] = self[(r2, j)];
            self[(r2, j)] = t;
        }
    }

    /// max |a_ij| (infinity norm of the element set, used by the HPL
    /// residual check denominator).
    pub fn max_abs(&self) -> f64 {
        self.data.iter().fold(0.0_f64, |m, v| m.max(v.abs()))
    }

    /// Frobenius-ish elementwise comparison.
    pub fn allclose(&self, other: &Matrix, rtol: f64, atol: f64) -> bool {
        if self.rows != other.rows || self.cols != other.cols {
            return false;
        }
        for j in 0..self.cols {
            for i in 0..self.rows {
                let (x, y) = (self[(i, j)], other[(i, j)]);
                if (x - y).abs() > atol + rtol * y.abs() {
                    return false;
                }
            }
        }
        true
    }
}

impl std::ops::Index<(usize, usize)> for Matrix {
    type Output = f64;
    #[inline(always)]
    fn index(&self, (i, j): (usize, usize)) -> &f64 {
        debug_assert!(i < self.rows && j < self.cols);
        &self.data[i + j * self.ld]
    }
}

impl std::ops::IndexMut<(usize, usize)> for Matrix {
    #[inline(always)]
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f64 {
        debug_assert!(i < self.rows && j < self.cols);
        &mut self.data[i + j * self.ld]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn index_roundtrip() {
        let mut m = Matrix::zeros(3, 2);
        m[(2, 1)] = 5.0;
        assert_eq!(m[(2, 1)], 5.0);
        assert_eq!(m.as_slice()[2 + 3], 5.0); // column-major position
    }

    #[test]
    fn eye_matvec_is_identity() {
        let m = Matrix::eye(4);
        let x = vec![1.0, 2.0, 3.0, 4.0];
        assert_eq!(m.matvec(&x), x);
    }

    #[test]
    fn gemm_small_known() {
        // [[1,2],[3,4]] * [[5,6],[7,8]] = [[19,22],[43,50]]
        let a = Matrix::from_rows(2, 2, &[1.0, 2.0, 3.0, 4.0]);
        let b = Matrix::from_rows(2, 2, &[5.0, 6.0, 7.0, 8.0]);
        let mut c = Matrix::zeros(2, 2);
        Matrix::gemm_acc(&mut c, &a, &b);
        assert_eq!(c, Matrix::from_rows(2, 2, &[19.0, 22.0, 43.0, 50.0]));
    }

    #[test]
    fn gemm_accumulates() {
        let a = Matrix::eye(2);
        let b = Matrix::eye(2);
        let mut c = Matrix::eye(2);
        Matrix::gemm_acc(&mut c, &a, &b);
        assert_eq!(c[(0, 0)], 2.0);
        assert_eq!(c[(0, 1)], 0.0);
    }

    #[test]
    fn block_and_set_block_roundtrip() {
        let m = Matrix::random_hpl(6, 6, 1);
        let b = m.block(2, 3, 3, 2);
        let mut m2 = Matrix::zeros(6, 6);
        m2.set_block(2, 3, &b);
        assert_eq!(m2[(2, 3)], m[(2, 3)]);
        assert_eq!(m2[(4, 4)], m[(4, 4)]);
        assert_eq!(m2[(0, 0)], 0.0);
    }

    #[test]
    fn swap_rows_partial_range() {
        let mut m = Matrix::from_rows(2, 3, &[1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        m.swap_rows(0, 1, 1, 3);
        assert_eq!(m[(0, 0)], 1.0); // untouched column
        assert_eq!(m[(0, 1)], 5.0);
        assert_eq!(m[(1, 2)], 3.0);
    }

    #[test]
    fn row_major_roundtrip() {
        let m = Matrix::random_hpl(5, 7, 3);
        let rm = m.to_row_major();
        let back = Matrix::from_row_major(5, 7, &rm);
        assert!(back.allclose(&m, 0.0, 0.0));
    }

    #[test]
    fn random_dd_is_diagonally_dominant() {
        let m = Matrix::random_dd(16, 9);
        for i in 0..16 {
            let off: f64 = (0..16).filter(|&j| j != i).map(|j| m[(i, j)].abs()).sum();
            assert!(m[(i, i)].abs() > off);
        }
    }

    #[test]
    fn allclose_detects_difference() {
        let a = Matrix::eye(3);
        let mut b = Matrix::eye(3);
        assert!(a.allclose(&b, 1e-12, 1e-12));
        b[(1, 1)] += 1e-6;
        assert!(!a.allclose(&b, 1e-12, 1e-12));
    }
}
