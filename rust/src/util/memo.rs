//! Sharded process-wide memoization keyed by content hash
//! ([`crate::util::hash`]).
//!
//! A [`MemoCache`] is a `static`-friendly concurrent map from 128-bit
//! content digests to cached values. Producers are pure and
//! deterministic (the whole point of content addressing), so the cache
//! needs no invalidation: a key either maps to *the* value or is
//! absent. Under rayon fan-out two threads may race to compute the same
//! coordinate; both compute bit-identical values and the first insert
//! wins, so later lookups return a stable (pointer-stable, for `Arc`
//! values) result.
//!
//! Shards are lazily initialized through `OnceLock`, keeping
//! [`MemoCache::new`] `const` so caches can live in `static`s without
//! any registration step. Hit/miss counters feed the `cimone bench`
//! cold-vs-warm report, and [`MemoCache::reset`] gives the perf harness
//! a true cold start.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Mutex, OnceLock};

/// Shard count (power of two — keys index by low bits).
const SHARDS: usize = 16;

/// Hit/miss/occupancy snapshot of one cache.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CacheStats {
    pub hits: u64,
    pub misses: u64,
    pub entries: usize,
}

impl CacheStats {
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

/// A concurrent content-addressed cache; see the module docs.
pub struct MemoCache<V> {
    shards: OnceLock<Vec<Mutex<HashMap<u128, V>>>>,
    hits: AtomicU64,
    misses: AtomicU64,
}

impl<V> MemoCache<V> {
    /// `const` so caches can be `static`s.
    pub const fn new() -> MemoCache<V> {
        MemoCache { shards: OnceLock::new(), hits: AtomicU64::new(0), misses: AtomicU64::new(0) }
    }

    fn shards(&self) -> &[Mutex<HashMap<u128, V>>] {
        self.shards.get_or_init(|| (0..SHARDS).map(|_| Mutex::new(HashMap::new())).collect())
    }

    fn shard(&self, key: u128) -> &Mutex<HashMap<u128, V>> {
        &self.shards()[(key as usize) & (SHARDS - 1)]
    }
}

impl<V> Default for MemoCache<V> {
    fn default() -> Self {
        MemoCache::new()
    }
}

impl<V: Clone> MemoCache<V> {
    /// Return the cached value for `key`, computing and inserting it via
    /// `f` on a miss. Racing computations are resolved first-insert-wins,
    /// so the returned value is stable once any thread has inserted.
    pub fn get_or_insert_with(&self, key: u128, f: impl FnOnce() -> V) -> V {
        let shard = self.shard(key);
        let cached = shard.lock().unwrap().get(&key).cloned();
        if let Some(v) = cached {
            self.hits.fetch_add(1, Ordering::Relaxed);
            return v;
        }
        // Compute outside the lock; deterministic producers make racing
        // computations bit-identical, so which thread wins is invisible.
        let v = f();
        self.misses.fetch_add(1, Ordering::Relaxed);
        let mut m = shard.lock().unwrap();
        m.entry(key).or_insert(v).clone()
    }

    /// Fallible form: errors propagate and are never cached, so a
    /// transient failure does not poison the coordinate.
    pub fn get_or_try_insert_with<E>(
        &self,
        key: u128,
        f: impl FnOnce() -> Result<V, E>,
    ) -> Result<V, E> {
        let shard = self.shard(key);
        let cached = shard.lock().unwrap().get(&key).cloned();
        if let Some(v) = cached {
            self.hits.fetch_add(1, Ordering::Relaxed);
            return Ok(v);
        }
        let v = f()?;
        self.misses.fetch_add(1, Ordering::Relaxed);
        let mut m = shard.lock().unwrap();
        Ok(m.entry(key).or_insert(v).clone())
    }

    pub fn stats(&self) -> CacheStats {
        let entries = self.shards().iter().map(|s| s.lock().unwrap().len()).sum();
        CacheStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            entries,
        }
    }

    /// Drop every entry and zero the counters — the perf harness's cold
    /// start. Concurrent users are unaffected beyond recomputing.
    pub fn reset(&self) {
        for s in self.shards() {
            s.lock().unwrap().clear();
        }
        self.hits.store(0, Ordering::Relaxed);
        self.misses.store(0, Ordering::Relaxed);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;
    use std::sync::Arc;

    static CACHE: MemoCache<u64> = MemoCache::new();

    #[test]
    fn computes_once_then_hits() {
        let cache: MemoCache<u64> = MemoCache::new();
        let calls = AtomicUsize::new(0);
        let compute = || {
            calls.fetch_add(1, Ordering::SeqCst);
            42u64
        };
        assert_eq!(cache.get_or_insert_with(7, compute), 42);
        assert_eq!(cache.get_or_insert_with(7, compute), 42);
        assert_eq!(calls.load(Ordering::SeqCst), 1);
        let s = cache.stats();
        assert_eq!((s.hits, s.misses, s.entries), (1, 1, 1));
        assert!((s.hit_rate() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn static_cache_usable_without_registration() {
        assert_eq!(CACHE.get_or_insert_with(1, || 10), 10);
        assert_eq!(CACHE.get_or_insert_with(1, || 99), 10);
    }

    #[test]
    fn errors_propagate_and_do_not_poison() {
        let cache: MemoCache<u64> = MemoCache::new();
        let r: Result<u64, String> = cache.get_or_try_insert_with(3, || Err("transient".into()));
        assert_eq!(r, Err("transient".to_string()));
        assert_eq!(cache.stats().entries, 0);
        let r: Result<u64, String> = cache.get_or_try_insert_with(3, || Ok(5));
        assert_eq!(r, Ok(5));
        assert_eq!(cache.stats().entries, 1);
    }

    #[test]
    fn reset_clears_entries_and_counters() {
        let cache: MemoCache<u64> = MemoCache::new();
        cache.get_or_insert_with(1, || 1);
        cache.get_or_insert_with(1, || 1);
        cache.reset();
        let s = cache.stats();
        assert_eq!((s.hits, s.misses, s.entries), (0, 0, 0));
        assert_eq!(s.hit_rate(), 0.0);
    }

    #[test]
    fn racing_threads_agree_on_one_value() {
        let cache: Arc<MemoCache<Vec<u64>>> = Arc::new(MemoCache::new());
        let mut handles = Vec::new();
        for _ in 0..8 {
            let c = Arc::clone(&cache);
            handles.push(std::thread::spawn(move || c.get_or_insert_with(11, || vec![1, 2, 3])));
        }
        for h in handles {
            assert_eq!(h.join().unwrap(), vec![1, 2, 3]);
        }
        assert_eq!(cache.stats().entries, 1);
    }
}
