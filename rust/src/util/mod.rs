//! In-house substrates the offline build cannot pull from crates.io:
//! PRNG, CLI parsing, config files, ASCII tables/plots, stats, a bench
//! harness, a mini property-testing framework, and the content-hash +
//! memoization pair behind the estimation cache.

pub mod bench;
pub mod cli;
pub mod config;
pub mod hash;
pub mod json;
pub mod matrix;
pub mod memo;
pub mod prop;
pub mod rng;
pub mod stats;
pub mod table;
pub mod units;

pub use matrix::Matrix;
pub use rng::Rng;
