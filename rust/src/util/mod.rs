//! In-house substrates the offline build cannot pull from crates.io:
//! PRNG, CLI parsing, config files, ASCII tables/plots, stats, a bench
//! harness and a mini property-testing framework.

pub mod bench;
pub mod cli;
pub mod config;
pub mod json;
pub mod matrix;
pub mod prop;
pub mod rng;
pub mod stats;
pub mod table;
pub mod units;

pub use matrix::Matrix;
pub use rng::Rng;
