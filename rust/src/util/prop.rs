//! Mini property-testing framework (proptest is unavailable offline).
//!
//! `forall` draws `cases` random inputs from a generator and checks a
//! property; on failure it retries with 16 fresh draws of decreasing
//! "size" (shrink-lite) and reports the smallest failing case it saw.

use crate::util::rng::Rng;

/// Generator: draws a value of the given size class from the RNG.
pub trait Gen<T> {
    fn gen(&self, rng: &mut Rng, size: usize) -> T;
}

impl<T, F: Fn(&mut Rng, usize) -> T> Gen<T> for F {
    fn gen(&self, rng: &mut Rng, size: usize) -> T {
        self(rng, size)
    }
}

/// Result of a property run.
#[derive(Debug)]
pub enum PropResult<T> {
    Pass { cases: usize },
    Fail { case: T, seed: u64, message: String },
}

/// Run `prop` on `cases` random draws. Deterministic for a given seed.
pub fn forall<T: Clone + std::fmt::Debug>(
    seed: u64,
    cases: usize,
    gen: impl Gen<T>,
    prop: impl Fn(&T) -> Result<(), String>,
) -> PropResult<T> {
    let mut rng = Rng::new(seed);
    for case_idx in 0..cases {
        // size grows with the case index so we probe small inputs first
        let size = 1 + case_idx * 4 / cases.max(1) * 8 + case_idx % 8;
        let value = gen.gen(&mut rng, size);
        if let Err(message) = prop(&value) {
            // shrink-lite: try smaller sizes to find a more minimal failure
            let mut best = (value, message);
            for s in (1..size).rev().take(16) {
                let cand = gen.gen(&mut rng, s);
                if let Err(m) = prop(&cand) {
                    best = (cand, m);
                }
            }
            return PropResult::Fail { case: best.0, seed, message: best.1 };
        }
    }
    PropResult::Pass { cases }
}

/// Assert helper: panics with the failing case on property violation.
pub fn check<T: Clone + std::fmt::Debug>(
    name: &str,
    seed: u64,
    cases: usize,
    gen: impl Gen<T>,
    prop: impl Fn(&T) -> Result<(), String>,
) {
    match forall(seed, cases, gen, prop) {
        PropResult::Pass { .. } => {}
        PropResult::Fail { case, seed, message } => {
            panic!("property `{name}` failed (seed={seed}): {message}\ncase: {case:?}");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        let r = forall(
            1,
            100,
            |rng: &mut Rng, size: usize| rng.range_usize(0, size.max(1) + 1),
            |&x| if x < 1_000_000 { Ok(()) } else { Err("too big".into()) },
        );
        matches!(r, PropResult::Pass { .. })
            .then_some(())
            .expect("should pass");
    }

    #[test]
    fn failing_property_reports_case() {
        let r = forall(
            2,
            100,
            |rng: &mut Rng, _| rng.range_usize(0, 100),
            |&x| if x < 5 { Ok(()) } else { Err(format!("{x} >= 5")) },
        );
        match r {
            PropResult::Fail { case, .. } => assert!(case >= 5),
            _ => panic!("should fail"),
        }
    }

    #[test]
    #[should_panic(expected = "property `demo` failed")]
    fn check_panics_with_name() {
        check(
            "demo",
            3,
            50,
            |rng: &mut Rng, _| rng.range_usize(0, 10),
            |_| Err("always".into()),
        );
    }

    #[test]
    fn deterministic_per_seed() {
        let gen = |rng: &mut Rng, _: usize| rng.next_u64();
        let collect = |seed| {
            let out = std::cell::RefCell::new(vec![]);
            let _ = forall(seed, 10, gen, |&v| {
                out.borrow_mut().push(v);
                Ok(())
            });
            out.into_inner()
        };
        assert_eq!(collect(7), collect(7));
        assert_ne!(collect(7), collect(8));
    }
}
