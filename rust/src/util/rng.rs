//! Deterministic xorshift64* PRNG.
//!
//! HPL fills its matrix with a reproducible pseudo-random sequence; every
//! simulation in this crate needs seeded determinism so experiments are
//! replayable. crates.io `rand` is unavailable offline, so this is the
//! canonical xorshift64* generator (Vigna 2016) plus the distributions we
//! need (uniform, normal via Box–Muller, integer ranges).

/// Seeded xorshift64* generator. Passes BigCrush for our purposes and is
/// 1 mul + 3 shifts per draw — cheap enough for trace generation.
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
    /// Cached second normal deviate from Box–Muller.
    spare: Option<f64>,
}

impl Rng {
    /// Create a generator from a seed. Seed 0 is remapped (xorshift
    /// requires nonzero state).
    pub fn new(seed: u64) -> Self {
        Rng { state: if seed == 0 { 0x9E3779B97F4A7C15 } else { seed }, spare: None }
    }

    /// Next raw 64-bit draw.
    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.state;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.state = x;
        x.wrapping_mul(0x2545F4914F6CDD1D)
    }

    /// Uniform in [0, 1).
    pub fn uniform(&mut self) -> f64 {
        // 53 high bits -> double mantissa
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in [0, n). Uses rejection to avoid modulo bias.
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0);
        let zone = u64::MAX - (u64::MAX % n);
        loop {
            let v = self.next_u64();
            if v < zone {
                return v % n;
            }
        }
    }

    /// Uniform usize in [lo, hi).
    pub fn range_usize(&mut self, lo: usize, hi: usize) -> usize {
        assert!(hi > lo);
        lo + self.below((hi - lo) as u64) as usize
    }

    /// Standard normal deviate (Box–Muller, cached pair).
    pub fn normal(&mut self) -> f64 {
        if let Some(s) = self.spare.take() {
            return s;
        }
        loop {
            let u = self.uniform();
            if u <= f64::MIN_POSITIVE {
                continue;
            }
            let v = self.uniform();
            let r = (-2.0 * u.ln()).sqrt();
            let theta = 2.0 * std::f64::consts::PI * v;
            self.spare = Some(r * theta.sin());
            return r * theta.cos();
        }
    }

    /// HPL-style matrix entries: uniform in [-0.5, 0.5) like HPL's
    /// `HPL_rand` fill.
    pub fn hpl_entry(&mut self) -> f64 {
        self.uniform() - 0.5
    }

    /// Fill a slice with HPL-style entries.
    pub fn fill_hpl(&mut self, buf: &mut [f64]) {
        for v in buf.iter_mut() {
            *v = self.hpl_entry();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn zero_seed_is_remapped() {
        let mut r = Rng::new(0);
        assert_ne!(r.next_u64(), 0);
    }

    #[test]
    fn uniform_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn uniform_mean_near_half() {
        let mut r = Rng::new(11);
        let n = 100_000;
        let sum: f64 = (0..n).map(|_| r.uniform()).sum();
        assert!((sum / n as f64 - 0.5).abs() < 0.01);
    }

    #[test]
    fn below_respects_bound_and_covers() {
        let mut r = Rng::new(3);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = r.below(10) as usize;
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(13);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.03, "var={var}");
    }

    #[test]
    fn hpl_entries_centered() {
        let mut r = Rng::new(17);
        let mut buf = vec![0.0; 10_000];
        r.fill_hpl(&mut buf);
        assert!(buf.iter().all(|v| (-0.5..0.5).contains(v)));
        let mean = buf.iter().sum::<f64>() / buf.len() as f64;
        assert!(mean.abs() < 0.02);
    }
}
