//! Summary statistics for benchmark samples and monitoring series.

/// Online/batch summary of a sample set.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub stddev: f64,
    pub min: f64,
    pub max: f64,
    pub median: f64,
}

impl Summary {
    /// Compute a summary; panics on an empty slice.
    pub fn of(samples: &[f64]) -> Summary {
        assert!(!samples.is_empty(), "Summary::of on empty sample set");
        let n = samples.len();
        let mean = samples.iter().sum::<f64>() / n as f64;
        let var = if n > 1 {
            samples.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / (n - 1) as f64
        } else {
            0.0
        };
        let mut sorted = samples.to_vec();
        // total_cmp: a NaN sample (e.g. a failed timing read) must not
        // panic the whole report — NaN sorts above every real number
        sorted.sort_by(|a, b| a.total_cmp(b));
        let median = if n % 2 == 1 {
            sorted[n / 2]
        } else {
            0.5 * (sorted[n / 2 - 1] + sorted[n / 2])
        };
        Summary {
            n,
            mean,
            stddev: var.sqrt(),
            min: sorted[0],
            max: sorted[n - 1],
            median,
        }
    }

    /// Coefficient of variation (stddev/mean), 0 for degenerate samples.
    pub fn cv(&self) -> f64 {
        if self.mean == 0.0 {
            0.0
        } else {
            self.stddev / self.mean.abs()
        }
    }
}

/// HPL FLOP count for an N×N solve: 2/3 N^3 + 3/2 N^2 (netlib formula).
pub fn hpl_flops(n: usize) -> f64 {
    let nf = n as f64;
    (2.0 / 3.0) * nf * nf * nf + 1.5 * nf * nf
}

/// GEMM FLOP count (multiply-add pairs counted as 2).
pub fn gemm_flops(m: usize, n: usize, k: usize) -> f64 {
    2.0 * m as f64 * n as f64 * k as f64
}

/// Convert (flops, seconds) to GFLOP/s.
pub fn gflops(flops: f64, seconds: f64) -> f64 {
    assert!(seconds > 0.0);
    flops / seconds / 1e9
}

/// Geometric mean (used for cross-experiment speedup aggregation).
pub fn geomean(xs: &[f64]) -> f64 {
    assert!(!xs.is_empty() && xs.iter().all(|&x| x > 0.0));
    (xs.iter().map(|x| x.ln()).sum::<f64>() / xs.len() as f64).exp()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basic() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(s.n, 4);
        assert!((s.mean - 2.5).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 4.0);
        assert!((s.median - 2.5).abs() < 1e-12);
        assert!((s.stddev - (5.0f64 / 3.0).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn summary_single_sample() {
        let s = Summary::of(&[7.0]);
        assert_eq!(s.stddev, 0.0);
        assert_eq!(s.median, 7.0);
    }

    #[test]
    fn odd_median() {
        assert_eq!(Summary::of(&[3.0, 1.0, 2.0]).median, 2.0);
    }

    #[test]
    #[should_panic]
    fn empty_panics() {
        Summary::of(&[]);
    }

    #[test]
    fn nan_sample_does_not_panic() {
        // a NaN sample used to panic the partial_cmp sort; now it sorts
        // last (total_cmp order) and the finite order statistics survive
        let s = Summary::of(&[2.0, f64::NAN, 1.0]);
        assert_eq!(s.n, 3);
        assert_eq!(s.min, 1.0);
        assert!(s.max.is_nan(), "NaN sorts above every real number");
        assert_eq!(s.median, 2.0);
        // all-NaN is equally panic-free
        let s = Summary::of(&[f64::NAN, f64::NAN]);
        assert!(s.median.is_nan());
    }

    #[test]
    fn hpl_flops_formula() {
        // N=1000: 2/3e9 + 1.5e6
        let f = hpl_flops(1000);
        assert!((f - (2.0 / 3.0 * 1e9 + 1.5e6)).abs() < 1.0);
    }

    #[test]
    fn gemm_flops_square() {
        assert_eq!(gemm_flops(10, 10, 10), 2000.0);
    }

    #[test]
    fn gflops_conversion() {
        assert!((gflops(2e9, 1.0) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn geomean_of_speedups() {
        let g = geomean(&[2.0, 8.0]);
        assert!((g - 4.0).abs() < 1e-12);
    }
}
