//! ASCII table + horizontal bar-chart renderers.
//!
//! Every paper figure we regenerate is printed through these, so bench
//! output is directly comparable with the paper's plots (same rows/series).

/// Simple aligned-column table.
#[derive(Debug, Default)]
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new<S: Into<String>>(headers: Vec<S>) -> Self {
        Table { headers: headers.into_iter().map(Into::into).collect(), rows: vec![] }
    }

    pub fn row<S: Into<String>>(&mut self, cells: Vec<S>) -> &mut Self {
        let cells: Vec<String> = cells.into_iter().map(Into::into).collect();
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells);
        self
    }

    pub fn n_rows(&self) -> usize {
        self.rows.len()
    }

    /// Render with column alignment; first column left, rest right.
    pub fn render(&self) -> String {
        let ncol = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            let mut line = String::from("|");
            for i in 0..ncol {
                if i == 0 {
                    line += &format!(" {:<w$} |", cells[i], w = widths[i]);
                } else {
                    line += &format!(" {:>w$} |", cells[i], w = widths[i]);
                }
            }
            line
        };
        let sep = {
            let mut s = String::from("+");
            for w in &widths {
                s += &"-".repeat(w + 2);
                s.push('+');
            }
            s
        };
        out += &sep;
        out.push('\n');
        out += &fmt_row(&self.headers, &widths);
        out.push('\n');
        out += &sep;
        out.push('\n');
        for row in &self.rows {
            out += &fmt_row(row, &widths);
            out.push('\n');
        }
        out += &sep;
        out
    }
}

/// Horizontal bar chart (one bar per labelled value) — stands in for the
/// paper's bar figures (Figs 3, 5, 7).
pub fn bar_chart(title: &str, entries: &[(String, f64)], unit: &str, width: usize) -> String {
    let max = entries.iter().map(|(_, v)| *v).fold(f64::MIN, f64::max).max(1e-30);
    let label_w = entries.iter().map(|(l, _)| l.len()).max().unwrap_or(0);
    let mut out = format!("== {title} ==\n");
    for (label, v) in entries {
        let n = ((v / max) * width as f64).round() as usize;
        out += &format!("{label:<label_w$} | {:<width$} {v:.1} {unit}\n", "#".repeat(n));
    }
    out
}

/// Grouped series chart: for each x-label, one value per series (Figs 4, 6, 7).
pub fn grouped_chart(
    title: &str,
    x_labels: &[String],
    series: &[(String, Vec<f64>)],
    unit: &str,
) -> String {
    let mut out = format!("== {title} ==\n");
    let label_w = series.iter().map(|(n, _)| n.len()).max().unwrap_or(0);
    let max = series
        .iter()
        .flat_map(|(_, vs)| vs.iter().copied())
        .fold(f64::MIN, f64::max)
        .max(1e-30);
    for (xi, x) in x_labels.iter().enumerate() {
        out += &format!("[{x}]\n");
        for (name, vals) in series {
            let v = vals[xi];
            let n = ((v / max) * 40.0).round() as usize;
            out += &format!("  {name:<label_w$} | {:<40} {v:.2} {unit}\n", "#".repeat(n));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(vec!["lib", "gflops"]);
        t.row(vec!["openblas", "244.9"]);
        t.row(vec!["blis-opt", "245.8"]);
        let s = t.render();
        assert!(s.contains("| lib      |"));
        assert!(s.contains("| openblas |  244.9 |"));
        assert_eq!(s.lines().count(), 6);
    }

    #[test]
    #[should_panic]
    fn row_arity_checked() {
        let mut t = Table::new(vec!["a", "b"]);
        t.row(vec!["only-one"]);
    }

    #[test]
    fn bar_chart_scales_to_max() {
        let s = bar_chart(
            "t",
            &[("a".into(), 10.0), ("b".into(), 5.0)],
            "GB/s",
            20,
        );
        // 'a' bar should be twice as long as 'b'
        let lines: Vec<&str> = s.lines().collect();
        let count = |l: &str| l.matches('#').count();
        assert_eq!(count(lines[1]), 20);
        assert_eq!(count(lines[2]), 10);
    }

    #[test]
    fn grouped_chart_includes_all_series() {
        let s = grouped_chart(
            "hpl",
            &["64".into(), "128".into()],
            &[
                ("openblas".into(), vec![139.0, 244.9]),
                ("blis".into(), vec![100.0, 165.0]),
            ],
            "Gflop/s",
        );
        assert!(s.contains("[64]"));
        assert!(s.contains("[128]"));
        assert_eq!(s.matches("openblas").count(), 2);
    }
}
