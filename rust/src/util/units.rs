//! Unit formatting helpers shared by reports, tables and the monitor.

/// Format a byte count with binary prefixes.
pub fn fmt_bytes(b: u64) -> String {
    const UNITS: [&str; 5] = ["B", "KiB", "MiB", "GiB", "TiB"];
    let mut v = b as f64;
    let mut u = 0;
    while v >= 1024.0 && u < UNITS.len() - 1 {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{b} B")
    } else {
        format!("{v:.2} {}", UNITS[u])
    }
}

/// Format a rate in GB/s (decimal, like STREAM reports).
pub fn fmt_gbs(bytes_per_sec: f64) -> String {
    format!("{:.1} GB/s", bytes_per_sec / 1e9)
}

/// Format GFLOP/s (the paper's HPL unit).
pub fn fmt_gflops(gf: f64) -> String {
    format!("{gf:.1} Gflop/s")
}

/// Format a duration in adaptive units.
pub fn fmt_secs(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.2} s")
    } else if s >= 1e-3 {
        format!("{:.2} ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.2} µs", s * 1e6)
    } else {
        format!("{:.0} ns", s * 1e9)
    }
}

/// Parse strings like "128", "4k", "2M", "1G" into u64 (CLI sizes).
pub fn parse_size(s: &str) -> Option<u64> {
    let s = s.trim();
    if s.is_empty() {
        return None;
    }
    let (num, mult) = match s.chars().last().unwrap() {
        'k' | 'K' => (&s[..s.len() - 1], 1u64 << 10),
        'm' | 'M' => (&s[..s.len() - 1], 1u64 << 20),
        'g' | 'G' => (&s[..s.len() - 1], 1u64 << 30),
        _ => (s, 1),
    };
    num.parse::<u64>().ok().map(|v| v * mult)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bytes_formatting() {
        assert_eq!(fmt_bytes(512), "512 B");
        assert_eq!(fmt_bytes(2048), "2.00 KiB");
        assert_eq!(fmt_bytes(64 * 1024 * 1024), "64.00 MiB");
    }

    #[test]
    fn gbs_matches_stream_style() {
        assert_eq!(fmt_gbs(41.9e9), "41.9 GB/s");
    }

    #[test]
    fn gflops_style() {
        assert_eq!(fmt_gflops(244.9), "244.9 Gflop/s");
    }

    #[test]
    fn secs_adaptive() {
        assert_eq!(fmt_secs(2.5), "2.50 s");
        assert_eq!(fmt_secs(0.0025), "2.50 ms");
        assert_eq!(fmt_secs(2.5e-6), "2.50 µs");
        assert_eq!(fmt_secs(3e-9), "3 ns");
    }

    #[test]
    fn parse_sizes() {
        assert_eq!(parse_size("128"), Some(128));
        assert_eq!(parse_size("4k"), Some(4096));
        assert_eq!(parse_size("2M"), Some(2 << 20));
        assert_eq!(parse_size("1G"), Some(1 << 30));
        assert_eq!(parse_size("x"), None);
        assert_eq!(parse_size(""), None);
    }
}
