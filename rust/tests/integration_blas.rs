//! Integration: the BLAS simulation stack — micro-kernel programs on the
//! RVV functional machine, driven by the blocked GEMM, against the naive
//! oracle and across libraries; plus the ISA retrofit pass on the real
//! kernel programs.

use std::sync::Arc;

use cimone::arch::presets;
use cimone::blas::gemm::gemm_acc;
use cimone::blas::library::BlasLibrary;
use cimone::isa::translate::rvv10_to_thead;
use cimone::ukernel::{KernelRegistry, PanelLayout};
use cimone::util::Matrix;

#[test]
fn all_registered_libraries_agree_on_the_same_gemm() {
    let socket = presets::sg2042().sockets[0].clone();
    let a = Matrix::random_hpl(48, 36, 1);
    let b = Matrix::random_hpl(36, 52, 2);
    let c0 = Matrix::random_hpl(48, 52, 3);
    let mut want = c0.clone();
    Matrix::gemm_acc(&mut want, &a, &b);
    for k in KernelRegistry::builtin().kernels() {
        let lib = BlasLibrary::for_socket(Arc::clone(k), &socket);
        let mut c = c0.clone();
        gemm_acc(&lib, &mut c, &a, &b).unwrap();
        assert!(c.allclose(&want, 1e-10, 1e-10), "{}", k.id);
    }
}

#[test]
fn translated_blis_kernel_runs_identically_on_the_machine() {
    // Section 3.3.1 end-to-end: take BLIS's RVV 1.0 micro-kernel program,
    // retrofit it to theadvector, execute both, demand bitwise equality.
    use cimone::isa::exec::VecMachine;
    let reg = KernelRegistry::builtin();
    for id in ["blis-lmul1", "blis-lmul4", "blis-rvv1-lmul2", "blis-rvv1-lmul4"] {
        let k = reg.get(id).unwrap();
        let (mr, nr) = k.tile();
        let layout = PanelLayout::new(mr, nr, 24);
        let prog10 = k.program(layout);
        let prog07 = rvv10_to_thead(&prog10).expect("retrofit");

        let a = Matrix::random_hpl(mr, 24, 7);
        let b = Matrix::random_hpl(24, nr, 8);
        let c = Matrix::random_hpl(mr, nr, 9);
        let mem = layout.pack(&a, &b, &c);

        let mut m10 = VecMachine::new(128, layout.mem_words()).unwrap();
        m10.mem = mem.clone();
        m10.run(&prog10).unwrap();
        let mut m07 = VecMachine::new(128, layout.mem_words()).unwrap();
        m07.mem = mem;
        m07.run(&prog07).unwrap();
        assert_eq!(m10.mem, m07.mem, "{id}: retrofit changed numerics");
    }
}

#[test]
fn lmul_schedules_bitwise_identical_through_blocked_gemm() {
    // the paper's invariant: the optimization changes the schedule, not
    // the math — even composed through the full macro-kernel loop nest
    let reg = KernelRegistry::builtin();
    let socket = presets::sg2042().sockets[0].clone();
    let lib1 = BlasLibrary::for_socket(reg.get("blis-lmul1").unwrap(), &socket);
    let lib4 = BlasLibrary::for_socket(reg.get("blis-lmul4").unwrap(), &socket);
    let a = Matrix::random_hpl(40, 24, 11);
    let b = Matrix::random_hpl(24, 28, 12);
    let mut c1 = Matrix::random_hpl(40, 28, 13);
    let mut c4 = c1.clone();
    gemm_acc(&lib1, &mut c1, &a, &b).unwrap();
    gemm_acc(&lib4, &mut c4, &a, &b).unwrap();
    assert!(c1.allclose(&c4, 0.0, 0.0), "LMUL=1 vs LMUL=4 must round identically");
}

#[test]
fn perf_ordering_matches_fig7_at_all_core_counts() {
    use cimone::blas::perf::PerfModel;
    let d = cimone::arch::platform::mcv2_dual();
    for cores in [1, 8, 16, 32, 64, 128] {
        let ob = PerfModel::by_id(&d, "openblas-c920").unwrap().node_gflops(cores);
        let bv = PerfModel::by_id(&d, "blis-lmul1").unwrap().node_gflops(cores);
        let bo = PerfModel::by_id(&d, "blis-lmul4").unwrap().node_gflops(cores);
        assert!(bv < ob, "vanilla BLIS must trail OpenBLAS at {cores} cores");
        assert!(bo > bv * 1.3, "optimization must pay off at {cores} cores");
        assert!((bo / ob) > 0.94, "parity at {cores} cores: {bo:.1} vs {ob:.1}");
    }
}

#[test]
fn cache_conclusion_holds_across_core_counts() {
    // Fig 6's reasoning chain: BLIS's blocking beats OpenBLAS's at every
    // measured core count, therefore BLIS's deficit is the micro-kernel
    use cimone::coordinator::experiments::fig6;
    for (cores, ob_l1, ob_l3, bl_l1, bl_l3) in fig6(&[1, 4], 0.4) {
        assert!(bl_l1 < ob_l1, "L1 at {cores}: {bl_l1:.2}% vs {ob_l1:.2}%");
        assert!(bl_l3 <= ob_l3 + 0.5, "L3 at {cores}: {bl_l3:.3}% vs {ob_l3:.3}%");
    }
}
