//! Integration: the declarative campaign engine — Workload/CampaignSpec
//! composed with the scheduler, monitor, and typed-error surface.

use cimone::cluster::{monte_cimone_v2, Monitor};
use cimone::coordinator::driver::{run_campaign, run_campaign_spec};
use cimone::coordinator::CampaignSpec;
use cimone::error::CimoneError;

#[test]
fn paper_default_spec_reproduces_seed_campaign() {
    // 9 jobs, same names, Fig-5 ordering invariants — the frozen
    // figure-reproduction script as a spec
    let r = run_campaign(64).expect("campaign");
    assert_eq!(r.jobs.len(), 9);
    let names: Vec<&str> = r.jobs.iter().map(|j| j.name.as_str()).collect();
    assert_eq!(
        names,
        [
            "stream-mcv1",
            "stream-mcv2-1s",
            "stream-mcv2-2s",
            "hpl-mcv1-full",
            "hpl-mcv2-1s",
            "hpl-mcv2-2n",
            "hpl-mcv2-2s",
            "hpl-blis-vanilla",
            "hpl-blis-opt",
        ]
    );
    let get = |n: &str| r.monitor.latest(n).unwrap();
    assert!(get("hpl-mcv1-full.gflops") < get("hpl-mcv2-1s.gflops"));
    assert!(get("hpl-mcv2-2n.gflops") < get("hpl-mcv2-2s.gflops"));
    assert!(get("hpl-blis-opt.gflops") > get("hpl-blis-vanilla.gflops"));
}

#[test]
fn unknown_partition_is_a_typed_error_not_a_panic() {
    let inv = monte_cimone_v2();
    let mut s = inv.scheduler();
    match s.submit("lost", "gpu", 1, 10.0) {
        Err(CimoneError::UnknownPartition(p)) => assert_eq!(p, "gpu"),
        other => panic!("expected UnknownPartition, got {other:?}"),
    }
}

#[test]
fn empty_campaign_spec_drains_to_zero_makespan() {
    let inv = monte_cimone_v2();
    let spec = CampaignSpec { workloads: vec![], validate_n: 48, ..Default::default() };
    let r = run_campaign_spec(&inv, &spec).unwrap();
    assert!(r.jobs.is_empty());
    assert_eq!(r.makespan_s, 0.0);
}

#[test]
fn monitor_latest_on_unrecorded_metric_is_none() {
    let mon = Monitor::new();
    assert_eq!(mon.latest("never.recorded"), None);
    // ... and stays None for metrics the campaign never produced
    let r = run_campaign(48).unwrap();
    assert_eq!(r.monitor.latest("hpl-mcv3.gflops"), None);
}

#[test]
fn spec_file_roundtrip_through_config() {
    // a campaign scenario the hardcoded driver could never express:
    // 2-node HPL on the MCv1 partition next to a dual-socket STREAM job
    let text = r#"
[campaign]
validate_n = 48

[[workload]]
kind = "hpl"
name = "hpl-mcv1-2n"
node = "mcv1"
partition = "mcv1"
nodes = 2
cores_per_node = 4
lib = "openblas-generic"

[[workload]]
kind = "stream"
name = "stream-dual"
node = "mcv2-dual"
partition = "mcv2"
threads = 128
"#;
    let spec = CampaignSpec::parse(text).unwrap();
    assert_eq!(spec.len(), 2);
    let inv = monte_cimone_v2();
    let r = run_campaign_spec(&inv, &spec).unwrap();
    assert_eq!(r.jobs.len(), 2);
    assert!(r.monitor.latest("hpl-mcv1-2n.gflops").unwrap() > 0.0);
    assert!(r.monitor.latest("stream-dual.bandwidth").unwrap() > 1e9);
    assert!(r.makespan_s > 0.0);
}

#[test]
fn oversubscribed_campaign_queues_and_completes() {
    // 4 single-node jobs on the 4-node mcv2 partition + one 4-wide job:
    // the wide job must wait for the whole partition, so the makespan
    // exceeds the longest single job
    let mut text = String::from("[campaign]\nvalidate_n = 48\n");
    for i in 0..4 {
        text.push_str(&format!(
            "\n[[workload]]\nkind = \"stream\"\nname = \"s{i}\"\nnode = \"mcv2\"\npartition = \"mcv2\"\nthreads = 64\n"
        ));
    }
    text.push_str(
        "\n[[workload]]\nkind = \"hpl\"\nname = \"wide\"\nnode = \"mcv2\"\npartition = \"mcv2\"\nnodes = 4\ncluster_nodes = 4\ncores_per_node = 64\n",
    );
    let spec = CampaignSpec::parse(&text).unwrap();
    let inv = monte_cimone_v2();
    let r = run_campaign_spec(&inv, &spec).unwrap();
    assert_eq!(r.jobs.len(), 5);
    let longest_single = r.jobs.iter().map(|j| j.runtime_s).fold(0.0f64, f64::max);
    assert!(
        r.makespan_s > longest_single,
        "wide job must queue: makespan {} vs longest {}",
        r.makespan_s,
        longest_single
    );
}
