//! Integration: the cluster layer — inventory, SLURM-like scheduling,
//! the end-to-end campaign, and the Fig 5 projections composed together.

use cimone::cluster::monte_cimone_v2;
use cimone::coordinator::driver::run_campaign;
use cimone::coordinator::experiments;

#[test]
fn campaign_end_to_end() {
    let r = run_campaign(96).expect("campaign");
    assert!(r.hpl_passed, "validation HPL failed: residual {}", r.hpl_residual);
    assert!(r.stream_validated);
    // all nine jobs scheduled and completed
    assert_eq!(r.jobs.len(), 9);
    assert!(r.makespan_s > 0.0 && r.makespan_s.is_finite());
}

#[test]
fn campaign_reproduces_fig5_ratios() {
    let r = run_campaign(64).unwrap();
    let get = |n: &str| r.monitor.latest(n).unwrap();
    let single = get("hpl-mcv2-1s.gflops");
    let two_node = get("hpl-mcv2-2n.gflops");
    let dual = get("hpl-mcv2-2s.gflops");
    let scaling_2n = two_node / single;
    let scaling_2s = dual / single;
    assert!((1.2..1.45).contains(&scaling_2n), "2-node {scaling_2n:.2} (paper 1.33)");
    assert!((1.70..1.82).contains(&scaling_2s), "dual {scaling_2s:.2} (paper 1.76)");
    // headline: MCv2 dual node vs MCv1 full cluster per-node
    let mcv1_cluster = get("hpl-mcv1-full.gflops");
    assert!((11.0..15.0).contains(&mcv1_cluster), "MCv1 cluster {mcv1_cluster:.1}");
}

#[test]
fn scheduler_respects_partition_boundaries() {
    let inv = monte_cimone_v2();
    let mut s = inv.scheduler();
    // the mcv2 partition has 4 nodes; a 5-node job must be rejected
    assert!(s.submit("too-big", "mcv2", 5, 10.0).is_err());
    // fill mcv1 completely, mcv2 stays usable
    s.submit("fill", "mcv1", 8, 100.0).unwrap();
    let id = s.submit("mcv2-job", "mcv2", 4, 10.0).unwrap();
    assert!(matches!(
        s.job(id).unwrap().state,
        cimone::sched::JobState::Running { .. }
    ));
}

#[test]
fn failure_injection_degrades_gracefully() {
    // drain an MCv2 node: 4-node jobs become unschedulable, 3-node jobs
    // still run; bringing it back restores capacity
    let inv = monte_cimone_v2();
    let mut s = inv.scheduler();
    let mcv2_first = inv.ids_of_platform("mcv2-pioneer")[0];
    assert!(s.partitions.get_mut("mcv2").unwrap().mark_down(mcv2_first));
    // partition now reports 3 schedulable nodes
    assert_eq!(s.partitions["mcv2"].size(), 3);
    assert!(s.submit("four-wide", "mcv2", 4, 10.0).is_err());
    let ok = s.submit("three-wide", "mcv2", 3, 10.0).unwrap();
    let job = s.job(ok).unwrap();
    assert!(matches!(job.state, cimone::sched::JobState::Running { .. }));
    assert!(!job.allocated.contains(&mcv2_first), "downed node must not be allocated");
    s.drain();
    assert!(s.partitions.get_mut("mcv2").unwrap().mark_up(mcv2_first));
    assert!(s.submit("four-wide-again", "mcv2", 4, 10.0).is_ok());
}

#[test]
fn switch_fanin_consistent_with_collectives() {
    // the topology model's gather must cost at least the flat model's
    // bcast for the same volume (fan-in can only hurt)
    use cimone::net::{Collectives, Link, Switch};
    let bytes = 5e7;
    for p in [2usize, 4, 8] {
        let flat = Collectives::new(Link::gbe(), p).bcast(bytes);
        let fanin = Switch::monte_cimone().gather_time(p, bytes);
        assert!(
            fanin >= 0.9 * flat,
            "p={p}: gather {fanin:.3}s vs bcast {flat:.3}s"
        );
    }
}

#[test]
fn experiments_are_deterministic() {
    // projections are pure functions of the calibrated models
    let a = experiments::fig5();
    let b = experiments::fig5();
    for (x, y) in a.iter().zip(&b) {
        assert_eq!(x.0, y.0);
        assert!((x.1 - y.1).abs() < 1e-12);
    }
    let (h1, s1) = experiments::headline();
    let (h2, s2) = experiments::headline();
    assert_eq!(h1, h2);
    assert_eq!(s1, s2);
}

#[test]
fn monitor_accumulates_campaign_series() {
    let r = run_campaign(64).unwrap();
    let streams = r.monitor.query_prefix("stream-");
    assert_eq!(streams.len(), 3);
    // MCv1 < MCv2 single < MCv2 dual bandwidth ordering
    let get = |n: &str| r.monitor.latest(n).unwrap();
    assert!(get("stream-mcv1.bandwidth") < get("stream-mcv2-1s.bandwidth"));
    assert!(get("stream-mcv2-1s.bandwidth") < get("stream-mcv2-2s.bandwidth"));
}
