//! Integration: HPL end-to-end through all three layers — the blocked LU
//! runs its trailing updates through the PJRT artifacts (Pallas micro-
//! kernel -> JAX graph -> HLO -> Rust), and the solution passes HPL's own
//! residual criterion.

use cimone::error::CimoneError;
use cimone::hpl::lu::{lu_blocked, lu_solve, native_update};
use cimone::hpl::validate::{hpl_residual, HPL_THRESHOLD};
use cimone::runtime::{entries, ArtifactManifest, Runtime};
use cimone::util::{Matrix, Rng};

fn runtime_or_skip() -> Option<Runtime> {
    let dir = ArtifactManifest::default_dir();
    if !std::path::Path::new(&format!("{dir}/manifest.json")).exists() {
        eprintln!("skipping: artifacts not built (run `make artifacts`)");
        return None;
    }
    Some(Runtime::with_dir(&dir).expect("runtime"))
}

#[test]
fn hpl_with_pjrt_trailing_updates_passes_validation() {
    let Some(mut rt) = runtime_or_skip() else { return };
    let n = 256; // == artifact geometry; nb == manifest nb
    let nb = rt.manifest.nb;
    let a = Matrix::random_hpl(n, n, 777);
    let mut rng = Rng::new(778);
    let b: Vec<f64> = (0..n).map(|_| rng.hpl_entry()).collect();

    let mut update = |c: &mut Matrix, l: &Matrix, u: &Matrix| {
        entries::trailing_update(&mut rt, c, l, u).map_err(CimoneError::from)
    };
    let f = lu_blocked(&a, nb, &mut update).expect("factorization");
    let x = lu_solve(&f, &b);

    let r = hpl_residual(&a, &x, &b);
    assert!(r < HPL_THRESHOLD, "PJRT-backed HPL residual {r}");
}

#[test]
fn pjrt_and_native_factorizations_agree() {
    let Some(mut rt) = runtime_or_skip() else { return };
    let n = 128;
    let nb = rt.manifest.nb;
    let a = Matrix::random_hpl(n, n, 999);

    let f_native = lu_blocked(&a, nb, &mut native_update).unwrap();
    let mut update = |c: &mut Matrix, l: &Matrix, u: &Matrix| {
        entries::trailing_update(&mut rt, c, l, u).map_err(CimoneError::from)
    };
    let f_pjrt = lu_blocked(&a, nb, &mut update).unwrap();

    assert_eq!(f_native.perm, f_pjrt.perm, "pivot sequences must match");
    assert!(
        f_native.lu.allclose(&f_pjrt.lu, 1e-9, 1e-9),
        "LU factors diverge between native and PJRT backends"
    );
}

#[test]
fn pjrt_residual_check_agrees_with_native_check() {
    let Some(mut rt) = runtime_or_skip() else { return };
    let n = rt.manifest.n_gemm;
    let a = Matrix::random_hpl(n, n, 555);
    let mut rng = Rng::new(556);
    let b: Vec<f64> = (0..n).map(|_| rng.hpl_entry()).collect();
    let f = lu_blocked(&a, 32, &mut native_update).unwrap();
    let x = lu_solve(&f, &b);

    let native = hpl_residual(&a, &x, &b);
    // rebuild the scaled residual from the PJRT numerator
    let num = entries::residual_inf(&mut rt, &a, &x, &b).unwrap();
    let denom = {
        use cimone::hpl::validate::{inf_norm, mat_inf_norm};
        f64::EPSILON * (mat_inf_norm(&a) * inf_norm(&x) + inf_norm(&b)) * n as f64
    };
    let pjrt = num / denom;
    // the numerator is a catastrophically-cancelled quantity (Ax-b ~ eps);
    // XLA's dot-product order differs from our column-major matvec, so only
    // a few-percent relative agreement is meaningful
    assert!(
        (native - pjrt).abs() < 0.05 * (native + pjrt) + 1e-12,
        "{native} vs {pjrt}"
    );
    assert!(pjrt < HPL_THRESHOLD);
}

#[test]
fn panel_solve_artifact_is_a_valid_trsm() {
    let Some(mut rt) = runtime_or_skip() else { return };
    let nb = rt.manifest.nb;
    let n = rt.manifest.n_gemm;
    // unit-lower L
    let mut l = Matrix::eye(nb);
    let mut rng = Rng::new(31337);
    for i in 0..nb {
        for j in 0..i {
            l[(i, j)] = rng.hpl_entry();
        }
    }
    let u = Matrix::random_hpl(nb, n, 31338);
    let out = rt
        .call("panel_solve_32", &[&l.to_row_major(), &u.to_row_major()])
        .expect("panel_solve");
    let x = Matrix::from_row_major(nb, n, &out[0]);
    // check L * X == U
    let mut lx = Matrix::zeros(nb, n);
    Matrix::gemm_acc(&mut lx, &l, &x);
    assert!(lx.allclose(&u, 1e-9, 1e-9), "panel_solve is not a TRSM");
}
