//! Integration tests for the assembler front end: the literate `.cim.md`
//! conformance suite, randomized round-trip properties over both
//! dialects, a seeded differential fuzz harness (assembled programs
//! executed on the vector machine vs the scalar GEMM oracle), source
//! location / caret diagnostics, bit-identity of the shipped example
//! listing with its generator twin, and the asm-source kernel sweep end
//! to end (cold vs warm cache).

use std::path::{Path, PathBuf};

use cimone::coordinator::scenario::{self, ScenarioMatrix, SweepOptions};
use cimone::isa::{assemble, assembler, disassemble, literate};
use cimone::isa::{Dialect, Inst, Lmul, Program, Sew, VType, VecMachine};
use cimone::ukernel::registry::blis_rvv1_lmul2;
use cimone::ukernel::{KernelFamily, KernelRegistry, PanelLayout};
use cimone::util::config::Config;
use cimone::util::prop;
use cimone::util::rng::Rng;
use cimone::util::Matrix;

fn repo_path(rel: &str) -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join(rel)
}

// ---------------------------------------------------------------------
// Literate conformance suite: every rust/tests/isa/*.cim.md must pass.
// ---------------------------------------------------------------------

#[test]
fn literate_conformance_suite_passes() {
    let dir = repo_path("rust/tests/isa");
    let mut files: Vec<PathBuf> = std::fs::read_dir(&dir)
        .unwrap_or_else(|e| panic!("{}: {e}", dir.display()))
        .filter_map(|e| e.ok().map(|e| e.path()))
        .filter(|p| p.to_string_lossy().ends_with(".cim.md"))
        .collect();
    files.sort();
    assert!(files.len() >= 3, "expected >= 3 conformance files, found {files:?}");
    for f in &files {
        let passed = literate::run_file(f).unwrap_or_else(|e| panic!("{e}"));
        assert!(passed > 0, "{}: ran zero cases", f.display());
    }
}

// ---------------------------------------------------------------------
// Round-trip properties: parse(render(p)) == p over arbitrary programs.
// ---------------------------------------------------------------------

/// An arbitrary well-formed program in the given dialect. Respects the
/// canonical-form constraints the renderer implies: RVV 1.0 `vsetvli`
/// always carries ta/ma, theadvector never does and spells only E64
/// loads (EEW comes from vtype), and a theadvector program carries at
/// least one `th.`-prefixed instruction so the dialect is inferable.
fn arbitrary_program(rng: &mut Rng, size: usize, dialect: Dialect) -> Program {
    let n = 1 + size.min(24);
    let mut p = Program::new(dialect);
    for _ in 0..n {
        let sew = match dialect {
            Dialect::Rvv10 => {
                if rng.below(2) == 0 {
                    Sew::E64
                } else {
                    Sew::E32
                }
            }
            Dialect::Thead071 => Sew::E64,
        };
        let v = rng.below(32) as u8;
        let f = rng.below(32) as u8;
        let addr = rng.range_usize(0, 64);
        let inst = match rng.below(10) {
            0 => {
                let lmul = [Lmul::M1, Lmul::M2, Lmul::M4, Lmul::M8][rng.below(4) as usize];
                let mut vt = VType::new(sew, lmul);
                if dialect == Dialect::Rvv10 {
                    vt.tail_agnostic = true;
                    vt.mask_agnostic = true;
                }
                Inst::Vsetvli { avl: rng.range_usize(1, 9), vtype: vt }
            }
            1 => Inst::Vle { sew, vd: v, addr },
            2 => Inst::Vse { sew, vs: v, addr },
            3 => Inst::VfmaccVf { vd: v, fs: f, vs2: rng.below(32) as u8 },
            4 => Inst::VfmulVf { vd: v, fs: f, vs2: rng.below(32) as u8 },
            5 => Inst::VfmvVf { vd: v, fs: f },
            6 => Inst::VfaddVv { vd: v, vs1: rng.below(32) as u8, vs2: rng.below(32) as u8 },
            7 => Inst::Fld { fd: f, addr },
            8 => Inst::Fsd { fs: f, addr },
            _ => Inst::FmaddD { fd: f, fs1: rng.below(32) as u8, fs2: rng.below(32) as u8, fs3: f },
        };
        p.push(inst);
    }
    if rng.below(2) == 0 {
        p.push(Inst::Addi);
        p.push(Inst::Bnez);
    }
    let has_vector = p.insts.iter().any(|i| {
        matches!(
            i,
            Inst::Vsetvli { .. }
                | Inst::Vle { .. }
                | Inst::Vse { .. }
                | Inst::VfmaccVf { .. }
                | Inst::VfmulVf { .. }
                | Inst::VfmvVf { .. }
                | Inst::VfaddVv { .. }
        )
    });
    if dialect == Dialect::Thead071 && !has_vector {
        p.push(Inst::Vle { sew: Sew::E64, vd: 8, addr: 0 });
    }
    p
}

/// Sprinkle comments, blank lines, directives and unused labels into a
/// rendered listing — all structure the assembler must see through.
fn decorate(text: &str, rng: &mut Rng) -> String {
    let mut out = vec!["# decorated listing".to_string(), ".globl kernel".to_string()];
    for (i, line) in text.lines().enumerate() {
        match rng.below(5) {
            0 => out.push(String::new()),
            1 => out.push(format!("    # noise {i}")),
            2 => out.push(format!("unused{i}:")),
            3 => out.push(".align 3".to_string()),
            _ => {}
        }
        out.push(line.to_string());
    }
    out.join("\n")
}

#[test]
fn roundtrip_property_both_dialects() {
    for (dialect, seed) in [(Dialect::Rvv10, 11u64), (Dialect::Thead071, 12u64)] {
        prop::check(
            "assemble(decorate(disassemble(p))) == p",
            seed,
            120,
            move |rng: &mut Rng, size: usize| {
                let p = arbitrary_program(rng, size, dialect);
                let text = decorate(&disassemble(&p), rng);
                (p, text)
            },
            |(p, text)| {
                let back = assemble(text).map_err(|e| e.to_string())?;
                if back == *p {
                    Ok(())
                } else {
                    Err(format!("round-trip changed the program:\n{text}"))
                }
            },
        );
    }
}

#[test]
fn builtin_kernels_roundtrip_through_text() {
    // the registered generator kernels survive disassemble -> assemble
    // bit-identically (the property test's anchor on real programs)
    for k in KernelRegistry::builtin().kernels() {
        let (mr, nr) = k.tile();
        let p = k.program(PanelLayout::new(mr, nr, 7));
        let back = assemble(&disassemble(&p)).unwrap_or_else(|e| panic!("{}: {e}", k.id));
        assert_eq!(back, p, "{}", k.id);
    }
}

// ---------------------------------------------------------------------
// Seeded differential fuzz: random kernel geometries, assembled and
// executed on the vector machine vs the scalar GEMM oracle.
// ---------------------------------------------------------------------

#[test]
fn differential_fuzz_vecmachine_vs_scalar_oracle() {
    let mut rng = Rng::new(0xC1_30_7E);
    let mut executed = 0usize;
    for round in 0..60 {
        let vlen = [128usize, 256, 512][rng.below(3) as usize];
        let lmul = [Lmul::M1, Lmul::M2][rng.below(2) as usize];
        let mr = [2usize, 4, 8][rng.below(3) as usize];
        let nr = rng.range_usize(1, 5);
        let kc = rng.range_usize(1, 13);
        let k_unroll = [1usize, 2, 4][rng.below(3) as usize];
        let l = PanelLayout::new(mr, nr, kc);
        let p = cimone::ukernel::generators::blis_rvv_program(vlen, lmul, k_unroll, l);
        if p.validate_register_groups(vlen).is_err() {
            continue; // infeasible corner of the random grid
        }
        // round-trip through text first: the executed program is the
        // *assembled* one, so the whole front end is under test
        let back = assemble(&disassemble(&p)).unwrap_or_else(|e| panic!("round {round}: {e}"));
        assert_eq!(back, p, "round {round}: text round-trip changed the program");

        let a = Matrix::random_hpl(mr, kc, rng.next_u64());
        let b = Matrix::random_hpl(kc, nr, rng.next_u64());
        let c = Matrix::random_hpl(mr, nr, rng.next_u64());
        let mut m = VecMachine::new(vlen, l.mem_words()).unwrap();
        m.mem = l.pack(&a, &b, &c);
        m.run(&back).unwrap_or_else(|e| panic!("round {round}: {e}"));
        let got = l.unpack_c(&m.mem);
        let mut want = c.clone();
        Matrix::gemm_acc(&mut want, &a, &b);
        assert!(
            got.allclose(&want, 1e-13, 1e-13),
            "round {round}: vlen={vlen} lmul={lmul:?} {mr}x{nr} kc={kc} u={k_unroll} diverged"
        );
        executed += 1;
    }
    assert!(executed >= 30, "only {executed} feasible fuzz rounds — generator too narrow");
}

// ---------------------------------------------------------------------
// Mixed-precision differential property: the SEW=32 kernel executed on
// the vector machine vs a scalar f32 oracle, across VLENs (the kernel
// side of HPL-MxP must be *exactly* single-precision, not fast-f64).
// ---------------------------------------------------------------------

#[test]
fn e32_differential_property_vecmachine_vs_f32_oracle() {
    #[derive(Clone, Debug)]
    struct Case {
        vlen: usize,
        lmul: Lmul,
        mr: usize,
        nr: usize,
        kc: usize,
        k_unroll: usize,
        seed: u64,
    }
    prop::check(
        "E32 kernel == scalar f32 GEMM oracle",
        0xE32_D1FF,
        80,
        |rng: &mut Rng, size: usize| Case {
            vlen: [128usize, 256, 512][rng.below(3) as usize],
            lmul: [Lmul::M1, Lmul::M2][rng.below(2) as usize],
            mr: [2usize, 4, 8][rng.below(3) as usize],
            nr: rng.range_usize(1, 5),
            kc: rng.range_usize(1, 2 + size.min(11)),
            k_unroll: [1usize, 2, 4][rng.below(3) as usize],
            seed: rng.next_u64(),
        },
        |c| {
            let l = PanelLayout::new(c.mr, c.nr, c.kc);
            let p = cimone::ukernel::generators::blis_rvv_program_sew(
                c.vlen, c.lmul, Sew::E32, c.k_unroll, l,
            );
            if p.validate_register_groups(c.vlen).is_err() {
                return Ok(()); // infeasible corner of the random grid
            }
            // the executed program is the *assembled* one, as in the
            // f64 fuzz harness: the text front end is under test too
            let back = assemble(&disassemble(&p)).map_err(|e| e.to_string())?;
            if back != p {
                return Err("text round-trip changed the E32 program".into());
            }
            let a = Matrix::random_hpl(c.mr, c.kc, c.seed);
            let b = Matrix::random_hpl(c.kc, c.nr, c.seed ^ 1);
            let cm = Matrix::random_hpl(c.mr, c.nr, c.seed ^ 2);
            let mut m = VecMachine::new(c.vlen, l.mem_words()).map_err(|e| e.to_string())?;
            m.mem = l.pack(&a, &b, &cm);
            m.run(&back).map_err(|e| e.to_string())?;
            let got = l.unpack_c(&m.mem);
            // scalar f32 oracle: every operand rounded to single
            // precision, multiply and accumulate rounded per k-step
            let mut want = Matrix::zeros(c.mr, c.nr);
            for i in 0..c.mr {
                for j in 0..c.nr {
                    let mut acc = cm[(i, j)] as f32;
                    for k in 0..c.kc {
                        acc += (a[(i, k)] as f32) * (b[(k, j)] as f32);
                    }
                    want[(i, j)] = acc as f64;
                }
            }
            if got.allclose(&want, 1e-5, 1e-5) {
                Ok(())
            } else {
                Err(format!(
                    "vlen={} lmul={:?} {}x{} kc={} u={} diverged from the f32 oracle",
                    c.vlen, c.lmul, c.mr, c.nr, c.kc, c.k_unroll
                ))
            }
        },
    );
}

#[test]
fn e32_kernel_numerics_are_genuinely_single_precision() {
    // the E32 run must disagree with the f64 oracle: if it matched at
    // f64 tightness, the machine silently skipped the f32 rounding
    let l = PanelLayout::new(4, 4, 8);
    let p = cimone::ukernel::generators::blis_rvv_program_sew(256, Lmul::M1, Sew::E32, 1, l);
    let a = Matrix::random_hpl(4, 8, 21);
    let b = Matrix::random_hpl(8, 4, 22);
    let c = Matrix::random_hpl(4, 4, 23);
    let mut m = VecMachine::new(256, l.mem_words()).unwrap();
    m.mem = l.pack(&a, &b, &c);
    m.run(&p).unwrap();
    let got = l.unpack_c(&m.mem);
    let mut f64_want = c.clone();
    Matrix::gemm_acc(&mut f64_want, &a, &b);
    assert!(
        !got.allclose(&f64_want, 1e-9, 1e-9),
        "E32 run matched the f64 oracle bit-tight — f32 rounding never engaged"
    );
    assert!(
        got.allclose(&f64_want, 1e-4, 1e-4),
        "E32 run is not even single-precision close to the f64 oracle"
    );
}

// ---------------------------------------------------------------------
// Diagnostics: file/line/col + caret excerpt on the public error type.
// ---------------------------------------------------------------------

#[test]
fn asm_errors_carry_source_location_and_caret() {
    let text = ".globl k\n    vsetvli t0, 4, e64, m2, ta, ma\n    vfmaac.vf v0, f1, v8\n";
    let e = assembler::assemble_named(text, "examples/broken.S").unwrap_err();
    assert_eq!((e.file.as_str(), e.line, e.col), ("examples/broken.S", 3, 5));
    assert_eq!(e.span, "vfmaac.vf".len());
    let shown = e.to_string();
    assert!(shown.contains("examples/broken.S:3:5"), "{shown}");
    assert!(shown.contains("vfmaac.vf v0, f1, v8"), "excerpt missing: {shown}");
    assert!(shown.contains("^^^^^^^^^"), "caret missing: {shown}");
    assert!(shown.contains("did you mean `vfmacc.vf`?"), "{shown}");
}

#[test]
fn asm_error_converts_into_the_crate_error() {
    let e: cimone::error::CimoneError = assemble("frobnicate v0\n").unwrap_err().into();
    let shown = e.to_string();
    assert!(shown.contains("unknown mnemonic"), "{shown}");
    assert!(shown.contains("1:1"), "location lost in conversion: {shown}");
}

// ---------------------------------------------------------------------
// The shipped example listing is bit-identical to its generator twin
// and flows through spec -> registry -> sweep end to end.
// ---------------------------------------------------------------------

fn example_kernel_section() -> cimone::util::config::Section {
    let cfg = Config::parse(
        "[[kernel]]\nid = \"dgemm-rvv1-8x8\"\nbase = \"blis-rvv1-lmul2\"\n\
         family = \"asm-source\"\npath = \"kernels/dgemm_rvv1_8x8.S\"\n\
         vlen = 256\nmr = 8\nnr = 8\nk_unroll = 1\n",
    )
    .unwrap();
    cfg.table_arrays["kernel"][0].clone()
}

#[test]
fn example_listing_matches_the_generator_bit_for_bit() {
    let dir = repo_path("examples");
    let mut reg = KernelRegistry::builtin();
    let k = reg.register_section_with_dir(&example_kernel_section(), Some(dir.as_path())).unwrap();
    assert_eq!(k.family, KernelFamily::AsmSource);

    // the generator's descriptor for the same tuning point
    let mut twin = blis_rvv1_lmul2();
    twin.id = "twin".into();
    twin.aliases = Vec::new();
    twin.vlen_bits = 256;
    twin.mr = 8;
    twin.nr = 8;
    twin.k_unroll = 1;
    twin.validate().unwrap();

    for kc in [1usize, 4, 40, 41] {
        let l = PanelLayout::new(8, 8, kc);
        let (pa, pg) = (k.program(l), twin.program(l));
        assert_eq!(pa.dialect, pg.dialect, "kc={kc}");
        assert_eq!(pa.insts, pg.insts, "kc={kc}: assembled != generated");
    }

    // and the assembled kernel computes C + A*B
    let a = Matrix::random_hpl(8, 24, 7);
    let b = Matrix::random_hpl(24, 8, 8);
    let c = Matrix::random_hpl(8, 8, 9);
    let out = k.run(&a, &b, &c).unwrap();
    let mut want = c.clone();
    Matrix::gemm_acc(&mut want, &a, &b);
    assert!(out.allclose(&want, 1e-13, 1e-13));
}

#[test]
fn asm_kernel_sweep_spec_runs_end_to_end_and_cache_is_transparent() {
    let spec = repo_path("examples/sweep_asm_kernel.toml");
    let m = ScenarioMatrix::load(&spec.display().to_string()).unwrap();
    let opts = SweepOptions::default();
    let cold = scenario::dry_run_matrix_with(&m, &opts).unwrap().to_json().render();
    assert!(cold.contains("dgemm-rvv1-8x8"), "asm kernel missing from sweep: {cold}");
    // warm pass (same process, caches populated) must be byte-identical
    let warm = scenario::dry_run_matrix_with(&m, &opts).unwrap().to_json().render();
    assert_eq!(cold, warm, "warm-cache sweep diverged from cold");
}
