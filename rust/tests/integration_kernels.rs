//! Integration: the open kernel registry.
//!
//! Three layers of pinning keep the refactor honest:
//!
//! 1. **Bit-for-bit program goldens** — the seed's four hand-written
//!    kernel generators are preserved here as reference
//!    implementations; the registry's built-in descriptors must emit
//!    *identical* instruction sequences at every KC depth tested.
//! 2. **Property tests** (`util::prop::forall`) — every registered
//!    kernel's GEMM program, executed on the functional vector machine,
//!    matches the scalar reference GEMM across random small shapes —
//!    including BLIS sweep variants at wider VLENs (the machine is
//!    VLEN-generic).
//! 3. **A pinned SG2042-vs-SG2044 kernel-tuning comparison from spec
//!    text** — the spec-file path of the `blas-tuning` story, with
//!    golden windows and a bit-for-bit rerun.

use std::sync::Arc;

use cimone::coordinator::scenario::{dry_run_matrix, ScenarioMatrix};
use cimone::error::CimoneError;
use cimone::isa::inst::{Dialect, Inst, Program};
use cimone::isa::rvv::{Lmul, Sew, VType};
use cimone::ukernel::{ablation, KernelDescriptor, KernelRegistry, PanelLayout};
use cimone::util::json::Json;
use cimone::util::{prop, Matrix, Rng};

// ---------------------------------------------------------------------
// 1. bit-for-bit program goldens (the seed's generators, verbatim)
// ---------------------------------------------------------------------

/// The seed's `BlisLmul1::program` (Fig 2a schedule), kept verbatim.
fn seed_blis_lmul1(l: PanelLayout) -> Program {
    const LANES: usize = 2;
    const MR: usize = 8;
    const NR: usize = 4;
    const REGS_PER_COL: usize = MR / LANES;
    let mut p = Program::new(Dialect::Rvv10);
    let mut vt = VType::new(Sew::E64, Lmul::M1);
    vt.tail_agnostic = true;
    vt.mask_agnostic = true;
    p.push(Inst::Vsetvli { avl: LANES, vtype: vt });
    for j in 0..NR {
        for r in 0..REGS_PER_COL {
            p.push(Inst::Vle {
                sew: Sew::E64,
                vd: (j * REGS_PER_COL + r) as u8,
                addr: l.c_offset(j) + r * LANES,
            });
        }
    }
    for k in 0..l.kc {
        for r in 0..REGS_PER_COL {
            let addr = l.a_offset(k) + r * LANES;
            p.push(Inst::Vle { sew: Sew::E64, vd: (16 + r) as u8, addr });
        }
        for j in 0..NR {
            p.push(Inst::Fld { fd: j as u8, addr: l.b_offset(k) + j });
            for r in 0..REGS_PER_COL {
                p.push(Inst::VfmaccVf {
                    vd: (j * REGS_PER_COL + r) as u8,
                    fs: j as u8,
                    vs2: (16 + r) as u8,
                });
            }
        }
        p.push(Inst::Addi);
        p.push(Inst::Addi);
        p.push(Inst::Bnez);
    }
    for j in 0..NR {
        for r in 0..REGS_PER_COL {
            p.push(Inst::Vse {
                sew: Sew::E64,
                vs: (j * REGS_PER_COL + r) as u8,
                addr: l.c_offset(j) + r * LANES,
            });
        }
    }
    p
}

/// The seed's `BlisLmul4::program` (Fig 2b schedule), kept verbatim.
fn seed_blis_lmul4(l: PanelLayout) -> Program {
    const MR: usize = 8;
    const NR: usize = 4;
    let mut p = Program::new(Dialect::Rvv10);
    let mut vt = VType::new(Sew::E64, Lmul::M4);
    vt.tail_agnostic = true;
    vt.mask_agnostic = true;
    p.push(Inst::Vsetvli { avl: MR, vtype: vt });
    for j in 0..NR {
        p.push(Inst::Vle { sew: Sew::E64, vd: (j * 4) as u8, addr: l.c_offset(j) });
    }
    for k in 0..l.kc {
        p.push(Inst::Vle { sew: Sew::E64, vd: 16, addr: l.a_offset(k) });
        for j in 0..NR {
            p.push(Inst::Fld { fd: j as u8, addr: l.b_offset(k) + j });
            p.push(Inst::VfmaccVf { vd: (j * 4) as u8, fs: j as u8, vs2: 16 });
        }
        p.push(Inst::Addi);
        p.push(Inst::Addi);
        p.push(Inst::Bnez);
    }
    for j in 0..NR {
        p.push(Inst::Vse { sew: Sew::E64, vs: (j * 4) as u8, addr: l.c_offset(j) });
    }
    p
}

/// The seed's `OpenblasC920::program`, kept verbatim.
fn seed_openblas_c920(l: PanelLayout) -> Program {
    const NR: usize = 4;
    const GROUP_ELEMS: usize = 4;
    let mut p = Program::new(Dialect::Thead071);
    let vt = VType::new(Sew::E64, Lmul::M2);
    p.push(Inst::Vsetvli { avl: GROUP_ELEMS, vtype: vt });
    for j in 0..NR {
        p.push(Inst::Vle { sew: Sew::E64, vd: (j * 2) as u8, addr: l.c_offset(j) });
        let hi = l.c_offset(j) + GROUP_ELEMS;
        p.push(Inst::Vle { sew: Sew::E64, vd: (8 + j * 2) as u8, addr: hi });
    }
    for k in 0..l.kc {
        for j in 0..NR {
            p.push(Inst::Fld { fd: j as u8, addr: l.b_offset(k) + j });
        }
        p.push(Inst::Vle { sew: Sew::E64, vd: 16, addr: l.a_offset(k) });
        p.push(Inst::Vle { sew: Sew::E64, vd: 18, addr: l.a_offset(k) + GROUP_ELEMS });
        for j in 0..NR {
            p.push(Inst::VfmaccVf { vd: (j * 2) as u8, fs: j as u8, vs2: 16 });
            p.push(Inst::VfmaccVf { vd: (8 + j * 2) as u8, fs: j as u8, vs2: 18 });
        }
        p.push(Inst::Addi);
        p.push(Inst::Addi);
        p.push(Inst::Bnez);
    }
    for j in 0..NR {
        p.push(Inst::Vse { sew: Sew::E64, vs: (j * 2) as u8, addr: l.c_offset(j) });
        let hi = l.c_offset(j) + GROUP_ELEMS;
        p.push(Inst::Vse { sew: Sew::E64, vs: (8 + j * 2) as u8, addr: hi });
    }
    p
}

/// The seed's `OpenblasGeneric::program`, kept verbatim.
fn seed_openblas_generic(l: PanelLayout) -> Program {
    const MR: usize = 4;
    const NR: usize = 4;
    let mut p = Program::new(Dialect::Rvv10);
    for j in 0..NR {
        for i in 0..MR {
            p.push(Inst::Fld { fd: (16 + j * MR + i) as u8, addr: l.c_offset(j) + i });
        }
    }
    for k in 0..l.kc {
        for i in 0..MR {
            p.push(Inst::Fld { fd: i as u8, addr: l.a_offset(k) + i });
        }
        for j in 0..NR {
            p.push(Inst::Fld { fd: (4 + j) as u8, addr: l.b_offset(k) + j });
        }
        for j in 0..NR {
            for i in 0..MR {
                let acc = (16 + j * MR + i) as u8;
                p.push(Inst::FmaddD { fd: acc, fs1: i as u8, fs2: (4 + j) as u8, fs3: acc });
            }
        }
        p.push(Inst::Addi);
        p.push(Inst::Addi);
        p.push(Inst::Bnez);
    }
    for j in 0..NR {
        for i in 0..MR {
            p.push(Inst::Fsd { fs: (16 + j * MR + i) as u8, addr: l.c_offset(j) + i });
        }
    }
    p
}

#[test]
fn builtin_descriptors_reproduce_the_seed_programs_bit_for_bit() {
    let reg = KernelRegistry::builtin();
    type SeedGen = fn(PanelLayout) -> Program;
    let goldens: [(&str, SeedGen); 4] = [
        ("blis-lmul1", seed_blis_lmul1),
        ("blis-lmul4", seed_blis_lmul4),
        ("openblas-c920", seed_openblas_c920),
        ("openblas-generic", seed_openblas_generic),
    ];
    for (id, seed) in goldens {
        let k = reg.get(id).unwrap();
        let (mr, nr) = k.tile();
        for kc in [1usize, 2, 7, 64, 128] {
            let l = PanelLayout::new(mr, nr, kc);
            let got = k.program(l);
            let want = seed(l);
            assert_eq!(got.dialect, want.dialect, "{id} kc={kc}");
            assert_eq!(got.insts, want.insts, "{id} kc={kc}: program drifted from the seed");
        }
    }
}

#[test]
fn seed_instruction_count_formulas_still_hold() {
    // the per-k-step counts the paper's Fig 2 reasoning is built on
    let reg = KernelRegistry::builtin();
    let kc = 10;
    let count = |id: &str| {
        let k = reg.get(id).unwrap();
        let (mr, nr) = k.tile();
        k.program(PanelLayout::new(mr, nr, kc)).len()
    };
    assert_eq!(count("blis-lmul1"), 1 + 16 + 16 + kc * 27);
    assert_eq!(count("blis-lmul4"), 1 + 4 + 4 + kc * 12);
    assert_eq!(count("openblas-c920"), 1 + 8 + 8 + kc * 17);
    assert_eq!(count("openblas-generic"), 16 + 16 + kc * 27);
}

// ---------------------------------------------------------------------
// 2. property tests: machine execution vs the scalar oracle
// ---------------------------------------------------------------------

#[test]
fn prop_every_registered_kernel_matches_scalar_gemm() {
    let reg = KernelRegistry::builtin();
    // built-ins plus BLIS sweep variants at every supported wider VLEN
    // (the functional machine is VLEN-generic now)
    let mut kernels: Vec<Arc<KernelDescriptor>> = reg.kernels().cloned().collect();
    for vlen in [256usize, 512, 1024] {
        for lmul in [Lmul::M1, Lmul::M2, Lmul::M4] {
            for unroll in [1usize, 4] {
                let k = ablation::point(vlen, lmul, unroll);
                if k.validate().is_ok() {
                    kernels.push(Arc::new(k));
                }
            }
        }
    }
    assert!(kernels.len() > 20, "sweep variants must widen the pool: {}", kernels.len());
    prop::check(
        "registered kernel GEMM == scalar reference GEMM",
        0xC1A0,
        64,
        |rng: &mut Rng, size: usize| {
            let kc = rng.range_usize(1, size.clamp(1, 24) + 2);
            (rng.range_usize(0, kernels.len()), kc, rng.next_u64())
        },
        |&(ki, kc, seed)| {
            let k = &kernels[ki];
            let (mr, nr) = k.tile();
            let a = Matrix::random_hpl(mr, kc, seed);
            let b = Matrix::random_hpl(kc, nr, seed ^ 1);
            let c = Matrix::random_hpl(mr, nr, seed ^ 2);
            let out = k.run(&a, &b, &c).map_err(|e| format!("{}: {e}", k.id))?;
            let mut want = c.clone();
            Matrix::gemm_acc(&mut want, &a, &b);
            if out.allclose(&want, 1e-12, 1e-12) {
                Ok(())
            } else {
                Err(format!("{} kc={kc}: tile mismatch", k.id))
            }
        },
    );
}

#[test]
fn prop_vector_kernels_round_identically_across_vlen() {
    // same rank-1 order => bit-identical tiles, whatever the VLEN/LMUL
    // grouping — the paper's "optimization changes the schedule, not
    // the math" invariant, generalized to the whole sweep space
    let baseline = ablation::point(128, Lmul::M1, 1);
    prop::check(
        "sweep points round identically",
        0xC1A1,
        32,
        |rng: &mut Rng, size: usize| (rng.range_usize(1, size.clamp(1, 16) + 2), rng.next_u64()),
        |&(kc, seed)| {
            let a = Matrix::random_hpl(8, kc, seed);
            let b = Matrix::random_hpl(kc, 4, seed ^ 1);
            let c = Matrix::random_hpl(8, 4, seed ^ 2);
            let want = baseline.run(&a, &b, &c).map_err(|e| e.to_string())?;
            for vlen in [128usize, 256, 512] {
                for lmul in [Lmul::M1, Lmul::M2, Lmul::M4] {
                    let k = ablation::point(vlen, lmul, 2);
                    if k.validate().is_err() {
                        continue;
                    }
                    let out = k.run(&a, &b, &c).map_err(|e| format!("{}: {e}", k.id))?;
                    if !out.allclose(&want, 0.0, 0.0) {
                        return Err(format!("{} kc={kc}: rounding drifted", k.id));
                    }
                }
            }
            Ok(())
        },
    );
}

// ---------------------------------------------------------------------
// 3. the pinned SG2042-vs-SG2044 kernel-tuning comparison (spec text)
// ---------------------------------------------------------------------

const TUNING_SPEC: &str = r#"
# SG2042 vs SG2044 kernel tuning, as data: one 64-core DGEMM ablation
# crossed over platforms x registered kernels, plus a custom deeper
# unroll derived in-spec.
[campaign]
validate_n = 48

[[kernel]]
id = "blis-rvv1-u8"
base = "blis-rvv1-lmul2"
k_unroll = 8

[[workload]]
kind = "blis-ablation"
name = "dgemm"
platform = "mcv2-pioneer"
partition = "mcv2"
lib = "blis-lmul1"
cores = 64

[matrix]
platforms = ["mcv2-pioneer", "sg2044"]
libs = ["blis-lmul1", "blis-lmul4", "blis-rvv1-lmul2", "blis-rvv1-u8"]
"#;

#[test]
fn golden_kernel_tuning_comparison_is_pinned_and_reproducible() {
    let matrix = ScenarioMatrix::parse(TUNING_SPEC).unwrap();
    let report = dry_run_matrix(&matrix).unwrap();
    assert_eq!(report.scenarios.len(), 8, "2 platforms x 4 kernels");

    let gf = |name: &str| report.outcome(name).unwrap().hpl_gflops;
    // golden windows, anchored to Fig 7's 128-core numbers halved to one
    // socket (BLIS vanilla ~165/2, BLIS opt ~245.8/1.76) and the SG2044
    // evaluation's uplift
    let pins = [
        ("mcv2-pioneer/blis-lmul1", 80.0, 105.0),
        ("mcv2-pioneer/blis-lmul4", 125.0, 155.0),
        ("sg2044/blis-lmul1", 160.0, 190.0),
        ("sg2044/blis-rvv1-lmul2", 235.0, 275.0),
    ];
    for (name, lo, hi) in pins {
        let v = gf(name);
        assert!((lo..hi).contains(&v), "{name}: {v:.1} left the golden window [{lo}, {hi})");
    }
    // the acceptance punchlines: LMUL=4 > LMUL=1 on the SG2042...
    assert!(gf("mcv2-pioneer/blis-lmul4") > 1.3 * gf("mcv2-pioneer/blis-lmul1"));
    // ...and a native-RVV 1.0 kernel wins the SG2044 column
    let sg2044_best = report
        .scenarios
        .iter()
        .filter(|o| o.name.starts_with("sg2044/"))
        .max_by(|a, b| a.hpl_gflops.total_cmp(&b.hpl_gflops))
        .unwrap();
    assert!(
        sg2044_best.name.contains("blis-rvv1"),
        "SG2044 winner must be native RVV 1.0, got {} at {:.1}",
        sg2044_best.name,
        sg2044_best.hpl_gflops
    );
    // the custom in-spec kernel (deeper unroll) really participates and
    // lands between its base's neighbours, not at zero
    let custom = gf("sg2044/blis-rvv1-u8");
    assert!(custom > 200.0, "custom kernel row: {custom:.1}");

    // bit-for-bit rerun: the golden numbers cannot wander
    let rerun = dry_run_matrix(&matrix).unwrap();
    assert_eq!(rerun, report);

    // spec render round-trips, custom [[kernel]] included
    let back = ScenarioMatrix::parse(&matrix.render()).unwrap();
    assert_eq!(back, matrix);
}

#[test]
fn blas_tuning_builtin_json_reports_the_acceptance_numbers() {
    // what `cimone sweep --matrix blas-tuning --dry-run --json` emits,
    // validated through our own parser
    let report = dry_run_matrix(&ScenarioMatrix::blas_tuning()).unwrap();
    let parsed = Json::parse(&report.to_json().render()).unwrap();
    let rows = parsed.get("scenarios").unwrap().as_arr().unwrap();
    assert_eq!(rows.len(), 8);
    let gf = |name: &str| {
        rows.iter()
            .find(|r| r.get("name").unwrap().as_str() == Some(name))
            .unwrap_or_else(|| panic!("missing scenario {name}"))
            .get("hpl_gflops")
            .unwrap()
            .as_f64()
            .unwrap()
    };
    // LMUL=4 > LMUL=1 on SG2042 (Fig 2's uplift, node level)
    assert!(gf("mcv2-pioneer/blis-lmul4") > 1.3 * gf("mcv2-pioneer/blis-lmul1"));
    // the native-RVV 1.0 kernel is the SG2044 winner
    let native = gf("sg2044/blis-rvv1-lmul2");
    for other in ["sg2044/blis-lmul1", "sg2044/blis-lmul4", "sg2044/blis-rvv1-lmul4"] {
        assert!(native > gf(other), "{other}: {:.1} !< {native:.1}", gf(other));
    }
}

// ---------------------------------------------------------------------
// typed-error surface
// ---------------------------------------------------------------------

#[test]
fn unknown_kernels_are_typed_everywhere() {
    use cimone::cluster::monte_cimone_v2;
    use cimone::coordinator::workload::{BlisAblationWorkload, HplWorkload, Workload};
    let inv = monte_cimone_v2();
    // estimation-time resolution (registry travels with the inventory)
    let w = BlisAblationWorkload {
        name: "x".into(),
        partition: "mcv2".into(),
        platform: "mcv2-dual".into(),
        lib: "mkl".into(),
        cores: 128,
        runtime_s: 3600.0,
    };
    assert!(matches!(
        w.estimate(&inv),
        Err(CimoneError::UnknownKernel { ref name, .. }) if name == "mkl"
    ));
    let w = HplWorkload {
        name: "h".into(),
        partition: "mcv2".into(),
        nodes: 1,
        platform: "mcv2-pioneer".into(),
        cluster_nodes: 1,
        cores_per_node: 64,
        lib: Some("mkl".into()),
        fabric: None,
    };
    assert!(matches!(
        w.estimate(&inv),
        Err(CimoneError::UnknownKernel { ref name, .. }) if name == "mkl"
    ));
}

#[test]
fn kernel_aliases_resolve_end_to_end_from_spec_text() {
    use cimone::coordinator::CampaignSpec;
    // the seed's `blis-opt` / `openblas` spellings still work in specs
    let spec = CampaignSpec::parse(
        "[[workload]]\nkind = \"blis-ablation\"\nname = \"b\"\npartition = \"mcv2\"\nlib = \"blis-opt\"\n\n\
         [[workload]]\nkind = \"hpl\"\nname = \"h\"\nplatform = \"mcv2\"\npartition = \"mcv2\"\n\
         cores_per_node = 64\nlib = \"openblas\"\n",
    )
    .unwrap();
    let inv = spec.build_inventory().unwrap();
    let rows = cimone::coordinator::dry_run_spec(&inv, &spec).unwrap();
    assert_eq!(rows.len(), 2);
    for r in &rows {
        assert!(r.headline > 0.0, "{}: {}", r.name, r.headline);
    }
}
