//! Integration: the network layer, property-tested.
//!
//! `util::prop::forall` drives randomized checks over the collective
//! cost models, the switch flow model and the fabric registry — the
//! analytic invariants (monotonicity, closed-form bounds, permutation
//! invariance, strict 10 GbE dominance) that the golden scenario suite
//! relies on but cannot probe exhaustively. Byte counts are drawn as
//! integer-valued f64 so per-port sums are exact and the permutation
//! property can assert bit-for-bit equality.

use cimone::coordinator::CampaignSpec;
use cimone::error::CimoneError;
use cimone::net::{Collectives, Fabric, FabricRegistry, Link, Switch};
use cimone::util::prop::check;
use cimone::util::rng::Rng;

/// Random rank count in [2, 16] (the gbe-flat switch's port range).
fn draw_p(rng: &mut Rng) -> usize {
    rng.range_usize(2, 17)
}

/// Integer-valued payload in [1 B, ~2 GB]; the size class scales the
/// magnitude so small payloads (latency-dominated) are probed first.
fn draw_bytes(rng: &mut Rng, size: usize) -> f64 {
    let cap = 1u64 << (8 + (size % 24)); // 256 B .. ~2 GB
    rng.range_usize(1, cap as usize + 1) as f64
}

/// A set of non-loopback flows on a 16-port switch.
fn draw_flows(rng: &mut Rng, size: usize) -> Vec<(usize, usize, f64)> {
    let count = 1 + size.min(31);
    (0..count)
        .map(|_| {
            let src = rng.range_usize(0, 16);
            let mut dst = rng.range_usize(0, 16);
            if dst == src {
                dst = (dst + 1) % 16;
            }
            (src, dst, draw_bytes(rng, size))
        })
        .collect()
}

// ---------------------------------------------------------------------
// collectives: monotonicity + closed-form bounds
// ---------------------------------------------------------------------

#[test]
fn prop_collectives_monotone_in_bytes_and_nonnegative() {
    check(
        "bcast/allreduce monotone + non-negative",
        11,
        400,
        |rng: &mut Rng, size| {
            let (a, b) = (draw_bytes(rng, size), draw_bytes(rng, size));
            (draw_p(rng), a.min(b), a.max(b))
        },
        |&(p, lo, hi)| {
            let c = Collectives::new(Link::gbe(), p);
            let ops: [fn(&Collectives, f64) -> f64; 2] =
                [Collectives::bcast, Collectives::allreduce];
            for f in ops {
                let (tlo, thi) = (f(&c, lo), f(&c, hi));
                if !(tlo >= 0.0 && thi >= 0.0) {
                    return Err(format!("negative time: {tlo} / {thi}"));
                }
                if tlo > thi {
                    return Err(format!("p={p}: t({lo})={tlo} > t({hi})={thi}"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_bcast_crossover_never_exceeds_either_closed_form() {
    // bcast picks min(binomial, pipelined ring); whatever the crossover
    // point, it must never exceed either closed form
    check(
        "bcast <= binomial and <= ring",
        13,
        400,
        |rng: &mut Rng, size| (draw_p(rng), draw_bytes(rng, size)),
        |&(p, bytes)| {
            let link = Link::gbe();
            let t = Collectives::new(link, p).bcast(bytes);
            let binomial = (p as f64).log2().ceil().max(1.0) * link.msg_time(bytes);
            let ring = (p - 1) as f64 * link.latency_s + bytes / link.payload_bytes_per_sec();
            if t > binomial {
                return Err(format!("p={p} bytes={bytes}: {t} > binomial {binomial}"));
            }
            if t > ring {
                return Err(format!("p={p} bytes={bytes}: {t} > ring {ring}"));
            }
            Ok(())
        },
    );
}

// ---------------------------------------------------------------------
// switch flow model: flat-link lower bound + permutation invariance
// ---------------------------------------------------------------------

#[test]
fn prop_flows_time_at_least_flat_link_time() {
    // fan-in can only hurt: the switch can never beat each flow running
    // alone on its own dedicated link
    check(
        "flows_time >= max flat msg_time",
        17,
        300,
        draw_flows,
        |flows: &Vec<(usize, usize, f64)>| {
            let sw = Switch::monte_cimone();
            let t = sw.flows_time(flows);
            let flat = flows
                .iter()
                .map(|&(_, _, b)| sw.link.msg_time(b))
                .fold(0.0f64, f64::max);
            if t < flat {
                return Err(format!("{t} < flat bound {flat} for {} flows", flows.len()));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_flows_time_permutation_invariant() {
    // integer byte counts make per-port sums exact, so reordering the
    // flow list must not change the answer at all
    check(
        "flows_time order-independent",
        19,
        300,
        draw_flows,
        |flows: &Vec<(usize, usize, f64)>| {
            let sw = Switch::monte_cimone();
            let t = sw.flows_time(flows);
            let mut reversed = flows.clone();
            reversed.reverse();
            let mut rotated = flows.clone();
            rotated.rotate_left(flows.len() / 2);
            let mut sorted = flows.clone();
            sorted.sort_by(|a, b| a.2.total_cmp(&b.2).then(a.0.cmp(&b.0)).then(a.1.cmp(&b.1)));
            for (label, perm) in
                [("reversed", &reversed), ("rotated", &rotated), ("sorted", &sorted)]
            {
                let tp = sw.flows_time(perm);
                if tp != t {
                    return Err(format!("{label}: {tp} != {t}"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_ring_shift_reduces_to_flat_exchange_on_nonblocking_fabric() {
    // the HPL projection swapped Collectives::exchange for
    // Switch::ring_shift_time; on a non-blocking switch the two must be
    // the *same* model (bit-for-bit — identical arithmetic), so the
    // golden HPL numbers could not move
    check(
        "ring shift == flat exchange when non-blocking",
        15,
        300,
        |rng: &mut Rng, size| (draw_p(rng), draw_bytes(rng, size)),
        |&(p, bytes)| {
            let flat = Collectives::new(Link::gbe(), p).exchange(bytes);
            let switched = Fabric::gbe_flat().switch().ring_shift_time(p, bytes);
            if switched != flat {
                return Err(format!("p={p} bytes={bytes}: switch {switched} != flat {flat}"));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_oversubscribed_switch_never_beats_nonblocking() {
    check(
        "oversubscription only hurts",
        23,
        300,
        draw_flows,
        |flows: &Vec<(usize, usize, f64)>| {
            let flat = Fabric::gbe_flat().switch().flows_time(flows);
            let over = Fabric::gbe_oversub().switch().flows_time(flows);
            if over < flat {
                return Err(format!("oversub {over} < non-blocking {flat}"));
            }
            Ok(())
        },
    );
}

// ---------------------------------------------------------------------
// 10 GbE strictly dominates 1 GbE
// ---------------------------------------------------------------------

#[test]
fn prop_ten_gbe_strictly_dominates_gbe() {
    let gbe = Fabric::gbe_flat();
    let ten = Fabric::ten_gbe_flat();
    check(
        "10 GbE < 1 GbE on every payload",
        29,
        400,
        |rng: &mut Rng, size| (draw_p(rng), draw_bytes(rng, size)),
        |&(p, bytes)| {
            let (cg, ct) = (gbe.collectives(p), ten.collectives(p));
            for (label, a, b) in [
                ("bcast", cg.bcast(bytes), ct.bcast(bytes)),
                ("allreduce", cg.allreduce(bytes), ct.allreduce(bytes)),
                ("msg", gbe.link.msg_time(bytes), ten.link.msg_time(bytes)),
                (
                    "gather",
                    gbe.switch().gather_time(p, bytes),
                    ten.switch().gather_time(p, bytes),
                ),
            ] {
                if b >= a {
                    return Err(format!("p={p} bytes={bytes}: 10GbE {label} {b} >= 1GbE {a}"));
                }
            }
            Ok(())
        },
    );
}

// ---------------------------------------------------------------------
// fabric registry + campaign-level typed errors
// ---------------------------------------------------------------------

#[test]
fn builtin_fabric_registry_resolves_ids_and_aliases() {
    let reg = FabricRegistry::builtin();
    assert_eq!(reg.ids(), ["gbe-flat", "gbe-oversub", "ten-gbe-flat"]);
    for (alias, id) in [("gbe", "gbe-flat"), ("1gbe", "gbe-flat"), ("10gbe", "ten-gbe-flat")] {
        assert_eq!(reg.get(alias).unwrap().id, id);
    }
    match reg.get("myrinet") {
        Err(CimoneError::UnknownFabric { id, known }) => {
            assert_eq!(id, "myrinet");
            assert!(known.contains("ten-gbe-flat"), "{known}");
        }
        other => panic!("expected UnknownFabric, got {other:?}"),
    }
}

#[test]
fn fleet_wider_than_the_switch_is_a_load_time_error() {
    // satellite of rust/src/net/topo.rs's fixed `ports: 16`: a 17-node
    // fleet on the paper's ToR switch is a typed error when the spec
    // loads, not an index panic inside flows_time mid-campaign
    let err = CampaignSpec::parse("[[fleet]]\nplatform = \"mcv2-pioneer\"\ncount = 17\n")
        .unwrap_err();
    match err {
        CimoneError::FabricTooSmall { fabric, ports, nodes } => {
            assert_eq!((fabric.as_str(), ports, nodes), ("gbe-flat", 16, 17));
        }
        other => panic!("expected FabricTooSmall, got {other:?}"),
    }
    // the 32-port 10 GbE fabric carries the same fleet
    let spec = CampaignSpec::parse(
        "[[fleet]]\nplatform = \"mcv2-pioneer\"\ncount = 17\nfabric = \"ten-gbe-flat\"\n",
    )
    .unwrap();
    assert_eq!(spec.build_inventory().unwrap().nodes.len(), 17);
}

#[test]
fn custom_fabric_spec_round_trips_and_misspellings_are_typed() {
    let spec = CampaignSpec::parse(
        "[[fabric]]\nid = \"gbe-8to1\"\nbase = \"gbe\"\nbackplane_factor = 0.125\n\n\
         [[fleet]]\nplatform = \"mcv2-pioneer\"\ncount = 8\nfabric = \"gbe-8to1\"\n",
    )
    .unwrap();
    assert_eq!(spec.fabric.as_deref(), Some("gbe-8to1"));
    let back = CampaignSpec::parse(&spec.render()).unwrap();
    assert_eq!(back, spec);

    // a misspelled override key must not silently clone the base
    let err = CampaignSpec::parse(
        "[[fabric]]\nid = \"typo\"\nbase = \"gbe\"\nbackplan_factor = 0.125\n",
    )
    .unwrap_err();
    assert!(
        matches!(err, CimoneError::Spec(ref m) if m.contains("unknown key `backplan_factor`")),
        "{err:?}"
    );
    // an invalid override is typed as InvalidFabric
    let err = CampaignSpec::parse(
        "[[fabric]]\nid = \"dud\"\nbase = \"gbe\"\nbackplane_factor = 2.0\n",
    )
    .unwrap_err();
    assert!(matches!(err, CimoneError::InvalidFabric { .. }), "{err:?}");
}

#[test]
fn shrink_lite_reports_a_failing_case_with_its_seed() {
    // the harness itself: a deliberately false property must surface a
    // concrete counterexample (guards the suite against vacuous passes)
    use cimone::util::prop::{forall, PropResult};
    let r = forall(
        31,
        200,
        |rng: &mut Rng, size| draw_bytes(rng, size),
        |&bytes| {
            // false: claims every payload crosses 1 GbE in under 1 ms
            if Link::gbe().msg_time(bytes) < 1e-3 {
                Ok(())
            } else {
                Err(format!("{bytes} B too slow"))
            }
        },
    );
    match r {
        PropResult::Fail { case, seed, .. } => {
            assert_eq!(seed, 31);
            assert!(Link::gbe().msg_time(case) >= 1e-3);
        }
        PropResult::Pass { .. } => panic!("property should have failed"),
    }
}
