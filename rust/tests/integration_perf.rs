//! Integration: the content-addressed estimation cache and the
//! streaming sweep engine's public surface.
//!
//! Covers the cache-correctness contract from the outside: content
//! hashes are stable across calls, distinct for every distinct tunable
//! (LMUL, K-unroll, VLEN, fabric, platform), and blind to cosmetic
//! fields; warm-cache sweeps return values equal to cold ones; and the
//! `cimone bench` suite produces a complete, deterministic report.
//!
//! NOTE: only `quick_bench_suite_is_deterministic_and_complete` resets
//! the caches (via the suite itself) — every other assertion here is
//! value-based, so concurrent resets cannot make them flaky.

use std::collections::BTreeMap;

use cimone::arch::PlatformRegistry;
use cimone::coordinator::{dry_run_matrix, dry_run_matrix_with, ScenarioMatrix, SweepOptions};
use cimone::isa::rvv::Lmul;
use cimone::net::FabricRegistry;
use cimone::perfsuite;
use cimone::ukernel::KernelRegistry;
use cimone::util::json::Json;

#[test]
fn kernel_content_hashes_are_stable_and_pairwise_distinct() {
    let reg = KernelRegistry::builtin();
    let mut seen: BTreeMap<u128, String> = BTreeMap::new();
    for k in reg.kernels() {
        let h = k.content_hash();
        assert_eq!(h, k.content_hash(), "{}: hash must be pure", k.id);
        if let Some(prev) = seen.insert(h, k.id.clone()) {
            panic!("kernel hash collision: `{prev}` vs `{}`", k.id);
        }
    }
    assert!(seen.len() >= 6, "expected the full builtin registry, got {}", seen.len());
}

#[test]
fn every_kernel_tunable_changes_the_hash() {
    let base = (*KernelRegistry::builtin().get("blis-lmul4").unwrap()).clone();
    let h0 = base.content_hash();
    let mut variants = Vec::new();
    let mut v = base.clone();
    v.lmul = Lmul::M2;
    variants.push(("lmul", v));
    let mut v = base.clone();
    v.k_unroll += 1;
    variants.push(("k_unroll", v));
    let mut v = base.clone();
    v.vlen_bits *= 2;
    variants.push(("vlen_bits", v));
    let mut v = base.clone();
    v.host_overhead += 0.01;
    variants.push(("host_overhead", v));
    let mut v = base.clone();
    v.nr += 2;
    variants.push(("tile", v));
    let mut hashes = vec![h0];
    for (what, v) in &variants {
        let h = v.content_hash();
        assert!(!hashes.contains(&h), "{what} change did not move the hash");
        hashes.push(h);
    }
    // cosmetic fields stay out of the digest: same estimate coordinate
    let mut v = base.clone();
    v.label = "respun label".into();
    v.aliases.push("some-alias".into());
    assert_eq!(v.content_hash(), h0, "label/aliases must not shift the coordinate");
}

#[test]
fn platform_and_fabric_hashes_track_content_not_cosmetics() {
    let preg = PlatformRegistry::builtin();
    let mut seen: BTreeMap<u128, String> = BTreeMap::new();
    for p in preg.platforms() {
        let h = p.content_hash();
        assert_eq!(h, p.content_hash(), "{}: hash must be pure", p.id);
        if let Some(prev) = seen.insert(h, p.id.clone()) {
            panic!("platform hash collision: `{prev}` vs `{}`", p.id);
        }
    }
    let dual = preg.get("mcv2-dual").unwrap();
    let mut cosmetic = (*dual).clone();
    cosmetic.label = "same machine, new sticker".into();
    assert_eq!(cosmetic.content_hash(), dual.content_hash());
    let mut tweaked = (*dual).clone();
    tweaked.power.idle_w += 1.0;
    assert_ne!(tweaked.content_hash(), dual.content_hash());

    let freg = FabricRegistry::builtin();
    let gbe = freg.get("gbe-flat").unwrap();
    let ten = freg.get("ten-gbe-flat").unwrap();
    assert_ne!(gbe.content_hash(), ten.content_hash());
    let mut lossy = (*gbe).clone();
    lossy.link.efficiency *= 0.5;
    assert_ne!(lossy.content_hash(), gbe.content_hash());
}

#[test]
fn streaming_top_k_through_the_coordinator_reexports() {
    let m = ScenarioMatrix::fabric_scaling();
    let full = dry_run_matrix(&m).unwrap();
    assert_eq!((full.total, full.truncated), (16, 0));
    let opts = SweepOptions { shard_size: 4, top_k: Some(3) };
    let top = dry_run_matrix_with(&m, &opts).unwrap();
    assert_eq!(top.scenarios.len(), 3);
    assert_eq!((top.total, top.truncated), (16, 13));
    // the baseline row survives, so speedup columns stay anchored
    assert_eq!(top.baseline().unwrap().name, full.baseline().unwrap().name);
    // kept rows carry the same outcomes as the full sweep, bit for bit
    for o in &top.scenarios {
        assert_eq!(Some(o), full.outcome(&o.name), "{}", o.name);
    }
    // the human-readable table states the cut
    assert!(top.render().contains("13 of 16 scenarios truncated"), "{}", top.render());
}

#[test]
fn quick_bench_suite_is_deterministic_and_complete() {
    let a = perfsuite::run(true).unwrap();
    assert_eq!(a.fingerprint.len(), 32, "{}", a.fingerprint);
    assert!(a.fingerprint.chars().all(|c| c.is_ascii_hexdigit()), "{}", a.fingerprint);
    let parsed = Json::parse(&a.json.render()).unwrap();
    for key in [
        "vec_machine_insts_per_s",
        "program_gen_per_s",
        "analyze_cold_per_s",
        "analyze_warm_per_s",
        "trace_sim_interval_accesses_per_s",
        "trace_sim_per_access_accesses_per_s",
        "trace_sim_speedup",
        "trace_memo_lookups_per_s",
        "scenarios_per_s_cold",
        "scenarios_per_s_warm",
        "warm_speedup",
        "full_codesign_total",
        "full_codesign_scenarios_per_s",
    ] {
        let v = parsed.get(key).and_then(Json::as_f64).unwrap_or(-1.0);
        assert!(v > 0.0, "{key} = {v}");
    }
    // the memo caches behind the estimation stack are observable: every
    // cache reports its counters, and the sweep-side caches saw traffic
    let caches = parsed.get("caches").expect("caches stats object");
    for name in ["programs", "analyses", "estimates", "traces"] {
        let c = caches.get(name).unwrap_or_else(|| panic!("caches.{name}"));
        for counter in ["hits", "misses", "entries", "hit_rate"] {
            let v = c.get(counter).and_then(Json::as_f64).unwrap_or(-1.0);
            assert!(v >= 0.0, "caches.{name}.{counter} = {v}");
        }
    }
    for name in ["analyses", "estimates", "traces"] {
        let hits = caches.get(name).and_then(|c| c.get("hits")).and_then(Json::as_f64);
        assert!(hits.unwrap_or(0.0) > 0.0, "caches.{name} saw no hits");
    }
    assert_eq!(
        parsed.get("determinism_fingerprint").and_then(Json::as_str),
        Some(a.fingerprint.as_str())
    );
    // a second run — warm process, whatever the cache state — must
    // fingerprint identically: the model outputs may never wander
    let b = perfsuite::run(true).unwrap();
    assert_eq!(b.fingerprint, a.fingerprint);
}
