//! Integration: the open platform API — registry resolution, typed
//! errors, the SG2044 / MCv3 successor platforms, and spec files that
//! pick their own fleet, end to end through the campaign engine.

use cimone::arch::platform::{self, PlatformRegistry};
use cimone::cluster::inventory::Inventory;
use cimone::coordinator::driver::{dry_run_spec, run_campaign_spec};
use cimone::coordinator::CampaignSpec;
use cimone::error::CimoneError;

#[test]
fn unknown_platform_id_is_a_typed_error() {
    let reg = PlatformRegistry::builtin();
    match reg.get("epyc-9654") {
        Err(CimoneError::UnknownPlatform { id, known }) => {
            assert_eq!(id, "epyc-9654");
            // the error lists what IS registered, for spec authors
            for builtin in ["mcv1-u740", "mcv2-pioneer", "mcv2-dual", "sg2044", "mcv3"] {
                assert!(known.contains(builtin), "{known}");
            }
        }
        other => panic!("expected UnknownPlatform, got {other:?}"),
    }
}

#[test]
fn duplicate_registration_is_rejected() {
    let mut reg = PlatformRegistry::builtin();
    // same id again
    assert!(matches!(
        reg.register(platform::sg2044()),
        Err(CimoneError::DuplicatePlatform(ref n)) if n == "sg2044"
    ));
    // fresh id but an alias that collides with an existing id
    let mut p = platform::sg2044();
    p.id = "sg2044-respin".into();
    p.aliases = vec!["mcv3".into()];
    assert!(matches!(reg.register(p), Err(CimoneError::DuplicatePlatform(ref n)) if n == "mcv3"));
    // the registry is unchanged after the failed registrations
    assert_eq!(reg.ids().len(), 6);
}

#[test]
fn platform_invariants_are_validated_on_registration() {
    let mut reg = PlatformRegistry::new();
    let mut p = platform::sg2044();
    p.desc.sockets[0].core.freq_hz = 0.0;
    match reg.register(p) {
        Err(CimoneError::InvalidPlatform { id, reason }) => {
            assert_eq!(id, "sg2044");
            assert!(reason.contains("frequency"), "{reason}");
        }
        other => panic!("expected InvalidPlatform, got {other:?}"),
    }
}

#[test]
fn successor_estimates_are_finite_and_ordered_vs_mcv2() {
    // one fleet holding every generation; jobs target each via platform id
    let reg = PlatformRegistry::builtin();
    let inv = Inventory::from_fleet(
        &reg,
        &[("mcv2-pioneer", 1), ("mcv2-dual", 1), ("sg2044", 1), ("mcv3", 1)],
    )
    .unwrap();

    let mut spec = CampaignSpec::new();
    for (name, platform, partition, cores) in [
        ("hpl-sg2042", "mcv2-pioneer", "mcv2", 64usize),
        ("hpl-sg2042x2", "mcv2-dual", "mcv2", 128),
        ("hpl-sg2044", "sg2044", "sg2044", 64),
        ("hpl-mcv3", "mcv3", "mcv3", 128),
    ] {
        spec.push(cimone::coordinator::WorkloadSpec::Hpl {
            name: name.into(),
            partition: partition.into(),
            nodes: 1,
            platform: platform.into(),
            cluster_nodes: 1,
            cores_per_node: cores,
            lib: None,
            fabric: None,
        });
    }
    spec.validate_n = 48;
    let r = run_campaign_spec(&inv, &spec).unwrap();
    let get = |n: &str| r.monitor.latest(n).unwrap();
    for name in ["hpl-sg2042", "hpl-sg2042x2", "hpl-sg2044", "hpl-mcv3"] {
        let v = get(&format!("{name}.gflops"));
        assert!(v.is_finite() && v > 0.0, "{name}: {v}");
    }
    // Brown et al.: SG2044 >= SG2042 on HPL; and the dual-socket MCv3
    // projection clears both MCv2 node types
    assert!(get("hpl-sg2044.gflops") >= get("hpl-sg2042.gflops"));
    assert!(get("hpl-mcv3.gflops") > get("hpl-sg2042x2.gflops"));
}

const SG2044_SPEC: &str = r#"
[campaign]
validate_n = 48

[[fleet]]
platform = "sg2044"
count = 4

[[workload]]
kind = "stream"
name = "stream-sg2044"
platform = "sg2044"
partition = "sg2044"
threads = 64

[[workload]]
kind = "hpl"
name = "hpl-sg2044-2n"
platform = "sg2044"
partition = "sg2044"
nodes = 2
cores_per_node = 64
"#;

#[test]
fn sg2044_spec_file_round_trips_through_the_engine() {
    let spec = CampaignSpec::parse(SG2044_SPEC).unwrap();
    let inv = spec.build_inventory().unwrap();
    assert_eq!(inv.nodes.len(), 4);
    assert_eq!(inv.node(0).hostname, "sg2044-01");

    let r = run_campaign_spec(&inv, &spec).unwrap();
    assert_eq!(r.jobs.len(), 2);
    assert!(r.hpl_passed);
    // STREAM on DDR5 beats the SG2042's 41.9 GB/s
    let bw = r.monitor.latest("stream-sg2044.bandwidth").unwrap();
    assert!(bw > 41.9e9, "{bw}");
    let gf = r.monitor.latest("hpl-sg2044-2n.gflops").unwrap();
    assert!(gf.is_finite() && gf > 100.0, "{gf}");
    // per-job power/energy landed in the monitor too
    assert!(r.monitor.latest("hpl-sg2044-2n.power_w").unwrap() > 55.0);
    assert!(r.monitor.latest("hpl-sg2044-2n.energy_j").unwrap() > 0.0);
    assert!(r.makespan_s > 0.0);
}

#[test]
fn dry_run_matches_engine_estimates_without_scheduling() {
    let spec = CampaignSpec::parse(SG2044_SPEC).unwrap();
    let inv = spec.build_inventory().unwrap();
    let rows = dry_run_spec(&inv, &spec).unwrap();
    let full = run_campaign_spec(&inv, &spec).unwrap();
    assert_eq!(rows.len(), full.jobs.len());
    for (a, b) in rows.iter().zip(&full.jobs) {
        assert_eq!(a.name, b.name);
        assert!((a.headline - b.headline).abs() < 1e-9);
        assert!((a.energy_j - b.energy_j).abs() < 1e-9);
    }
}

#[test]
fn custom_platform_spec_runs_end_to_end() {
    // a user-defined overclocked SG2044 defined entirely in the spec file
    let text = r#"
[campaign]
validate_n = 48

[[platform]]
id = "sg2044-oc"
base = "sg2044"
freq_ghz = 3.0
idle_w = 70.0
partition = "oc"

[[fleet]]
platform = "sg2044-oc"
count = 2

[[workload]]
kind = "hpl"
name = "hpl-oc"
platform = "sg2044-oc"
partition = "oc"
cores_per_node = 16
"#;
    let spec = CampaignSpec::parse(text).unwrap();
    let inv = spec.build_inventory().unwrap();
    assert_eq!(inv.nodes.len(), 2);
    let r = run_campaign_spec(&inv, &spec).unwrap();
    let oc = r.monitor.latest("hpl-oc.gflops").unwrap();
    assert!(oc.is_finite() && oc > 0.0);

    // the same job on the stock sg2044 is slower than the 3.0 GHz respin
    // (16 cores: the bandwidth-uncontended regime, where clock rules)
    let stock = CampaignSpec::parse(
        "[campaign]\nvalidate_n = 48\n\n[[fleet]]\nplatform = \"sg2044\"\ncount = 2\n\n\
         [[workload]]\nkind = \"hpl\"\nname = \"hpl-stock\"\nplatform = \"sg2044\"\npartition = \"sg2044\"\ncores_per_node = 16\n",
    )
    .unwrap();
    let r2 = run_campaign_spec(&stock.build_inventory().unwrap(), &stock).unwrap();
    let st = r2.monitor.latest("hpl-stock.gflops").unwrap();
    assert!(oc > st, "oc {oc:.1} vs stock {st:.1}");
}

#[test]
fn paper_campaign_is_untouched_by_the_redesign() {
    // the frozen 9-job campaign still reproduces byte-for-byte on the
    // default fleet built through the registry
    let spec = CampaignSpec::paper_default();
    let inv = spec.build_inventory().unwrap();
    assert_eq!(inv.nodes.len(), 12);
    assert_eq!(inv.node(0).hostname, "mc-01");
    assert_eq!(inv.node(11).hostname, "mcv2-04");
    let r = run_campaign_spec(&inv, &spec).unwrap();
    assert_eq!(r.jobs.len(), 9);
    let get = |n: &str| r.monitor.latest(n).unwrap();
    assert!((get("stream-mcv2-1s.bandwidth") - 41.9e9).abs() < 0.5e9);
    assert!(get("hpl-blis-opt.gflops") > get("hpl-blis-vanilla.gflops"));
}
