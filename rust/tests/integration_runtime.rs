//! Integration: the PJRT runtime executes the JAX/Pallas-authored
//! artifacts and agrees with native Rust numerics — the contract that
//! makes the three-layer architecture trustworthy.
//!
//! Requires `make artifacts` (skips cleanly if they're absent so
//! `cargo test` works on a fresh checkout).

use cimone::runtime::{entries, ArtifactManifest, Runtime};
use cimone::util::Matrix;

fn runtime_or_skip() -> Option<Runtime> {
    let dir = ArtifactManifest::default_dir();
    if !std::path::Path::new(&format!("{dir}/manifest.json")).exists() {
        eprintln!("skipping: artifacts not built (run `make artifacts`)");
        return None;
    }
    Some(Runtime::with_dir(&dir).expect("runtime"))
}

#[test]
fn manifest_covers_all_entry_points() {
    let Some(rt) = runtime_or_skip() else { return };
    for name in [
        "gemm_256",
        "gemm_lmul1_64",
        "trailing_update_256",
        "panel_solve_32",
        "residual_256",
        "stream_copy",
        "stream_scale",
        "stream_add",
        "stream_triad",
        "ukernel_lmul1",
        "ukernel_lmul4",
    ] {
        assert!(rt.manifest.entry(name).is_some(), "missing {name}");
    }
}

#[test]
fn gemm_artifact_matches_native() {
    let Some(mut rt) = runtime_or_skip() else { return };
    let n = rt.manifest.n_gemm;
    let a = Matrix::random_hpl(n, n, 11);
    let b = Matrix::random_hpl(n, n, 12);
    let got = entries::gemm(&mut rt, &a, &b).expect("gemm");
    let mut want = Matrix::zeros(n, n);
    Matrix::gemm_acc(&mut want, &a, &b);
    assert!(got.allclose(&want, 1e-9, 1e-9), "PJRT gemm disagrees with native");
}

#[test]
fn ukernel_artifacts_match_isa_machine() {
    // The same micro-panel through (a) the Pallas-authored HLO and (b) the
    // RVV functional machine running the BLIS schedules: one paper, three
    // layers, one answer.
    use cimone::ukernel::KernelRegistry;
    let Some(mut rt) = runtime_or_skip() else { return };
    let a = Matrix::random_hpl(8, 64, 21);
    let b = Matrix::random_hpl(64, 8, 22);
    let c = Matrix::random_hpl(8, 8, 23);
    let reg = KernelRegistry::builtin();
    for variant in ["lmul1", "lmul4"] {
        let pjrt = entries::ukernel(&mut rt, variant, &a, &b, &c).expect("pjrt ukernel");
        // ISA kernels are 8x4: split the 8-column problem into two calls
        let id = if variant == "lmul1" { "blis-lmul1" } else { "blis-lmul4" };
        let k = reg.get(id).unwrap();
        let left = k.run(&a, &b.block(0, 0, 64, 4), &c.block(0, 0, 8, 4)).expect("isa left");
        let right = k.run(&a, &b.block(0, 4, 64, 4), &c.block(0, 4, 8, 4)).expect("isa right");
        let mut isa = Matrix::zeros(8, 8);
        isa.set_block(0, 0, &left);
        isa.set_block(0, 4, &right);
        assert!(pjrt.allclose(&isa, 1e-12, 1e-12), "{variant}: PJRT vs ISA mismatch");
    }
}

#[test]
fn trailing_update_handles_shrinking_live_regions() {
    let Some(mut rt) = runtime_or_skip() else { return };
    let nb = rt.manifest.nb;
    for live in [256, 200, 64, 8] {
        let mut c = Matrix::random_hpl(live, live, live as u64);
        let a = Matrix::random_hpl(live, nb, live as u64 + 1);
        let b = Matrix::random_hpl(nb, live, live as u64 + 2);
        let mut want = c.clone();
        let mut neg = a.clone();
        for v in neg.as_mut_slice() {
            *v = -*v;
        }
        Matrix::gemm_acc(&mut want, &neg, &b);
        entries::trailing_update(&mut rt, &mut c, &a, &b).expect("trailing update");
        assert!(c.allclose(&want, 1e-10, 1e-10), "live={live}");
    }
}

#[test]
fn trailing_update_rejects_oversize() {
    let Some(mut rt) = runtime_or_skip() else { return };
    let n = rt.manifest.n_gemm;
    let mut c = Matrix::zeros(n + 1, n + 1);
    let a = Matrix::zeros(n + 1, rt.manifest.nb);
    let b = Matrix::zeros(rt.manifest.nb, n + 1);
    assert!(entries::trailing_update(&mut rt, &mut c, &a, &b).is_err());
}

#[test]
fn stream_artifacts_match_kernels() {
    let Some(mut rt) = runtime_or_skip() else { return };
    let n = rt.manifest.n_stream;
    let a: Vec<f64> = (0..n).map(|i| ((i * 7 + 1) % 1000) as f64 * 0.01).collect();
    let b: Vec<f64> = (0..n).map(|i| ((i * 13 + 5) % 1000) as f64 * 0.02).collect();

    let copy = entries::stream(&mut rt, "copy", &a, None).unwrap();
    assert_eq!(&copy[..64], &a[..64]);

    let scale = entries::stream(&mut rt, "scale", &a, None).unwrap();
    assert!((scale[17] - 3.0 * a[17]).abs() < 1e-12);

    let add = entries::stream(&mut rt, "add", &a, Some(&b)).unwrap();
    assert!((add[1234] - (a[1234] + b[1234])).abs() < 1e-12);

    let triad = entries::stream(&mut rt, "triad", &a, Some(&b)).unwrap();
    let mut want = vec![0.0; n];
    cimone::stream::kernels::triad(&mut want, &a, &b);
    for i in (0..n).step_by(n / 31) {
        assert!((triad[i] - want[i]).abs() < 1e-12, "at {i}");
    }
}

#[test]
fn residual_artifact_matches_native() {
    let Some(mut rt) = runtime_or_skip() else { return };
    let n = rt.manifest.n_gemm;
    let a = Matrix::random_dd(n, 31);
    let x: Vec<f64> = (0..n).map(|i| (i as f64 * 0.37).sin()).collect();
    let b = a.matvec(&x);
    // exact solution: residual ~ 0
    let r0 = entries::residual_inf(&mut rt, &a, &x, &b).unwrap();
    assert!(r0 < 1e-8, "{r0}");
    // perturbed: matches native computation
    let mut xp = x.clone();
    xp[n / 2] += 0.125;
    let got = entries::residual_inf(&mut rt, &a, &xp, &b).unwrap();
    let native = {
        let ax = a.matvec(&xp);
        ax.iter().zip(&b).map(|(y, bb)| (y - bb).abs()).fold(0.0_f64, f64::max)
    };
    assert!((got - native).abs() < 1e-9 * (1.0 + native), "{got} vs {native}");
}

#[test]
fn executable_shape_validation() {
    let Some(mut rt) = runtime_or_skip() else { return };
    // wrong input arity
    assert!(rt.call("gemm_256", &[&[0.0; 65536]]).is_err());
    // wrong element count
    assert!(rt.call("gemm_256", &[&[0.0; 100], &[0.0; 65536]]).is_err());
    // unknown entry
    assert!(rt.call("nonexistent", &[]).is_err());
}

#[test]
fn executables_are_cached() {
    let Some(mut rt) = runtime_or_skip() else { return };
    assert_eq!(rt.loaded_count(), 0);
    let a = vec![0.5; 8 * 64];
    let b = vec![0.25; 64 * 8];
    let c = vec![0.0; 64];
    rt.call("ukernel_lmul4", &[&a, &b, &c]).unwrap();
    rt.call("ukernel_lmul4", &[&a, &b, &c]).unwrap();
    assert_eq!(rt.loaded_count(), 1);
}
