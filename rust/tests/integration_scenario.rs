//! Integration: the scenario sweep engine, plus the golden regression
//! suite that pins the paper campaign's numbers so refactors can't
//! silently drift from the paper.
//!
//! The golden windows anchor each job to the paper's published value
//! (Fig 3 / Fig 5 / Fig 7), the power pins are exact (the platform power
//! models are plain affine arithmetic), and a re-run must reproduce
//! every row bit-for-bit.

use std::fs;

use cimone::arch::platform::PlatformRegistry;
use cimone::coordinator::scenario::{
    dry_run_matrix, run_matrix, ComparisonReport, MatrixAxes, ScenarioMatrix, ScenarioSpec,
};
use cimone::coordinator::{driver, CampaignSpec, WorkloadSpec};
use cimone::error::CimoneError;
use cimone::util::json::Json;

// ---------------------------------------------------------------------
// golden regression: the paper campaign
// ---------------------------------------------------------------------

/// Golden row: job name, paper-anchored headline window `[lo, hi)`,
/// exact average node power (W), and the node count its energy covers.
const GOLDEN_PAPER_CAMPAIGN: [(&str, f64, f64, f64, usize); 9] = [
    ("stream-mcv1", 1.0, 1.25, 29.8, 1),       // Fig 3: 1.1 GB/s
    ("stream-mcv2-1s", 41.4, 42.4, 149.6, 1),  // Fig 3: 41.9 GB/s
    ("stream-mcv2-2s", 79.9, 85.9, 199.6, 1),  // Fig 3: 82.9 GB/s
    ("hpl-mcv1-full", 11.0, 15.0, 29.8, 8),    // Fig 5: 13 GF/s
    ("hpl-mcv2-1s", 125.0, 155.0, 149.6, 1),   // Fig 5: 139 GF/s
    ("hpl-mcv2-2n", 150.0, 225.0, 149.6, 2),   // Fig 5: 185 GF/s
    ("hpl-mcv2-2s", 225.0, 265.0, 289.2, 1),   // Fig 5: 245 GF/s
    ("hpl-blis-vanilla", 150.0, 180.0, 289.2, 1), // Fig 7: 165 GF/s
    ("hpl-blis-opt", 225.0, 265.0, 289.2, 1),  // Fig 7: 245.8 GF/s
];

#[test]
fn golden_paper_campaign_pins_every_job_metric() {
    let r = driver::run_campaign(64).unwrap();
    assert!(r.hpl_passed, "residual {}", r.hpl_residual);
    assert!(r.stream_validated);
    assert_eq!(r.jobs.len(), GOLDEN_PAPER_CAMPAIGN.len());

    for ((name, lo, hi, watts, energy_nodes), j) in GOLDEN_PAPER_CAMPAIGN.iter().zip(&r.jobs) {
        assert_eq!(&j.name, name, "job order drifted");
        assert!(
            (*lo..*hi).contains(&j.headline),
            "{name}: headline {:.2} left the golden window [{lo}, {hi})",
            j.headline
        );
        // power models are affine: idle + per_core * active, exactly
        assert!(
            (j.avg_node_w - watts).abs() < 1e-9,
            "{name}: power {} != {watts}",
            j.avg_node_w
        );
        // energy-to-solution is power x modeled nodes x runtime, exactly
        let want_energy = j.avg_node_w * *energy_nodes as f64 * j.runtime_s;
        assert!(
            (j.energy_j - want_energy).abs() < 1e-9 * want_energy.max(1.0),
            "{name}: energy {} != {want_energy}",
            j.energy_j
        );
        assert!(j.runtime_s.is_finite() && j.runtime_s > 0.0, "{name}: {}", j.runtime_s);
        // the monitor carries the same rows
        assert_eq!(r.monitor.latest(&format!("{name}.power_w")), Some(j.avg_node_w));
        assert_eq!(r.monitor.latest(&format!("{name}.energy_j")), Some(j.energy_j));
        match j.metric {
            "gflops" => {
                assert_eq!(r.monitor.latest(&format!("{name}.gflops")), Some(j.headline));
            }
            "bandwidth" => {
                let bw = r.monitor.latest(&format!("{name}.bandwidth")).unwrap();
                assert!((bw - j.headline * 1e9).abs() < 1e-3 * bw, "{name}: {bw}");
            }
            other => panic!("{name}: unexpected metric family `{other}`"),
        }
    }

    // the BLIS ablation occupies its fixed slot, and the campaign's
    // makespan covers it
    assert_eq!(r.jobs[7].runtime_s, 3600.0);
    assert_eq!(r.jobs[8].runtime_s, 3600.0);
    assert!(r.makespan_s >= 3600.0, "{}", r.makespan_s);

    // bit-for-bit determinism: the golden numbers can't wander between runs
    let r2 = driver::run_campaign(64).unwrap();
    assert_eq!(r.makespan_s, r2.makespan_s);
    for (a, b) in r.jobs.iter().zip(&r2.jobs) {
        assert_eq!(a, b, "job `{}` not reproducible", a.name);
    }
}

// ---------------------------------------------------------------------
// sweep engine end to end
// ---------------------------------------------------------------------

#[test]
fn builtin_generation_matrix_reproduces_the_paper_headline() {
    let report = run_matrix(&ScenarioMatrix::generations()).unwrap();
    assert_eq!(report.scenarios.len(), 5);
    assert_eq!(report.baseline().unwrap().name, "mcv1-u740");

    let dual = report.outcome("mcv2-dual").unwrap();
    let (hpl_x, stream_x) = report.speedup_of(dual);
    let (hpl_x, stream_x) = (hpl_x.unwrap(), stream_x.unwrap());
    // the abstract: 127x HPL DP FLOP/s, 69x STREAM bandwidth per node
    assert!((100.0..160.0).contains(&hpl_x), "HPL uplift {hpl_x:.0}x (paper 127x)");
    assert!((55.0..85.0).contains(&stream_x), "STREAM uplift {stream_x:.0}x (paper 69x)");

    // every scenario actually ran: scheduled makespans, finite metrics
    for o in &report.scenarios {
        assert!(o.makespan_s > 0.0, "{}: {}", o.name, o.makespan_s);
        assert!(o.hpl_gflops.is_finite() && o.hpl_gflops > 0.0, "{}", o.name);
        assert!(o.gflops_per_w > 0.0, "{}", o.name);
    }
    // down the road: each generation's HPL beats its predecessor
    for pair in report.scenarios.windows(2) {
        assert!(
            pair[1].hpl_gflops > pair[0].hpl_gflops,
            "{} !> {}",
            pair[1].name,
            pair[0].name
        );
    }

    // a dry run of the same matrix estimates identical headline numbers
    // without scheduling anything
    let dry = dry_run_matrix(&ScenarioMatrix::generations()).unwrap();
    for (d, f) in dry.scenarios.iter().zip(&report.scenarios) {
        assert_eq!(d.name, f.name);
        assert_eq!(d.makespan_s, 0.0);
        assert!((d.hpl_gflops - f.hpl_gflops).abs() < 1e-9);
        assert!((d.stream_gbs - f.stream_gbs).abs() < 1e-9);
    }
}

/// HPL scaling efficiency at `nodes` for one (platform, fabric) leg of
/// the fabric-scaling matrix: GF/s at `nodes` over `nodes` x GF/s at 1.
fn scaling_eff(report: &ComparisonReport, platform: &str, fabric: &str, nodes: usize) -> f64 {
    let gf = |n: usize| -> f64 {
        report
            .outcome(&format!("{platform}/{n}n/{fabric}"))
            .unwrap_or_else(|| panic!("missing scenario {platform}/{n}n/{fabric}"))
            .hpl_gflops
    };
    gf(nodes) / (nodes as f64 * gf(1))
}

#[test]
fn golden_fabric_scaling_matrix_reproduces_the_fig5_effect() {
    // the paper's Fig 5 punchline, end to end through the sweep engine:
    // MCv1 scales almost linearly on the 1 GbE it shipped with, MCv2's
    // ~127x-faster nodes collapse on the same wire, and the MCv3-style
    // 10 GbE fabric restores the scaling
    let report = dry_run_matrix(&ScenarioMatrix::fabric_scaling()).unwrap();
    assert_eq!(report.scenarios.len(), 16, "2 platforms x 4 widths x 2 fabrics");

    let mcv1_gbe = scaling_eff(&report, "mcv1-u740", "gbe-flat", 8);
    let mcv2_gbe = scaling_eff(&report, "mcv2-pioneer", "gbe-flat", 8);
    let mcv2_ten = scaling_eff(&report, "mcv2-pioneer", "ten-gbe-flat", 8);
    // "the 1 Gb/s network was sufficient for obtaining almost an HPL
    // linear scaling" (MCv1)
    assert!(mcv1_gbe >= 0.90, "MCv1 on 1 GbE: {mcv1_gbe:.3}");
    // "... is no longer sufficient" (MCv2): materially below its own
    // 10 GbE run of the same jobs
    assert!(mcv2_gbe < 0.50, "MCv2 on 1 GbE: {mcv2_gbe:.3}");
    assert!(
        mcv2_ten >= 2.0 * mcv2_gbe,
        "10 GbE {mcv2_ten:.3} must at least double 1 GbE {mcv2_gbe:.3}"
    );
    assert!(mcv2_ten > 0.65, "MCv2 on 10 GbE: {mcv2_ten:.3}");
    // the fabric only matters once there is a wire: single-node runs are
    // fabric-independent
    for p in ["mcv1-u740", "mcv2-pioneer"] {
        let a = report.outcome(&format!("{p}/1n/gbe-flat")).unwrap().hpl_gflops;
        let b = report.outcome(&format!("{p}/1n/ten-gbe-flat")).unwrap().hpl_gflops;
        assert_eq!(a, b, "{p}: single-node HPL must not depend on the fabric");
    }
    // efficiency decays monotonically with node count on every leg
    for (p, f) in [
        ("mcv1-u740", "gbe-flat"),
        ("mcv1-u740", "ten-gbe-flat"),
        ("mcv2-pioneer", "gbe-flat"),
        ("mcv2-pioneer", "ten-gbe-flat"),
    ] {
        let effs: Vec<f64> = [1, 2, 4, 8].iter().map(|&n| scaling_eff(&report, p, f, n)).collect();
        for w in effs.windows(2) {
            assert!(w[1] <= w[0] + 1e-12, "{p}/{f}: efficiency rose {w:?}");
        }
    }

    // bit-for-bit rerun: the golden numbers cannot wander
    let rerun = dry_run_matrix(&ScenarioMatrix::fabric_scaling()).unwrap();
    assert_eq!(rerun, report);

    // unknown fabric ids on the axis are typed errors at load time
    let mut bad = ScenarioMatrix::fabric_scaling();
    bad.axes.fabrics.push("infiniband".into());
    assert!(matches!(
        bad.expand(),
        Err(CimoneError::UnknownFabric { ref id, .. }) if id == "infiniband"
    ));
}

#[test]
fn golden_warm_cache_sweeps_are_bit_identical_to_cold() {
    // the estimation cache's contract, end to end: replaying the
    // built-in matrices against warm caches must reproduce the cold
    // reports bit for bit — same rows, same render, same JSON text.
    // (Other tests in this binary run concurrently and share the
    // caches; that is the point — whatever the cache state, the values
    // never move.)
    cimone::perfsuite::reset_caches();
    let gens = ScenarioMatrix::generations();
    let cold = run_matrix(&gens).unwrap();
    let cold_json = cold.to_json().render();
    let cold_render = cold.render();
    let warm = run_matrix(&gens).unwrap();
    assert_eq!(warm, cold);
    assert_eq!(warm.to_json().render(), cold_json);
    assert_eq!(warm.render(), cold_render);

    // dry-run path too, on the wider fabric-scaling grid
    cimone::perfsuite::reset_caches();
    let fs_matrix = ScenarioMatrix::fabric_scaling();
    let cold = dry_run_matrix(&fs_matrix).unwrap();
    let cold_json = cold.to_json().render();
    let warm = dry_run_matrix(&fs_matrix).unwrap();
    assert_eq!(warm, cold);
    assert_eq!(warm.to_json().render(), cold_json);
}

const FABRIC_ABLATION_SPEC: &str = r#"
# MCv2 fleet, same jobs on the paper's 1 GbE vs the MCv3-style 10 GbE
[campaign]
validate_n = 48

[[fabric]]
id = "gbe-8to1"
base = "gbe-flat"
backplane_factor = 0.125

[[fleet]]
platform = "mcv2-pioneer"
count = 8

[[workload]]
kind = "hpl"
name = "hpl-8n"
platform = "mcv2-pioneer"
partition = "mcv2"
nodes = 8
cores_per_node = 64

[matrix]
fabrics = ["gbe-flat", "ten-gbe-flat", "gbe-8to1"]
"#;

#[test]
fn golden_fabric_ablation_scenario_is_pinned_and_reproducible() {
    let matrix = ScenarioMatrix::parse(FABRIC_ABLATION_SPEC).unwrap();
    let report = run_matrix(&matrix).unwrap();
    assert_eq!(report.scenarios.len(), 3);

    // scaling-efficiency window: 8-node GF/s over 8x the single-node
    // projection (the same number the hpl/model golden tests pin)
    let single = cimone::hpl::model::cluster_hpl_gflops(
        &cimone::hpl::model::ClusterConfig::hpl_default(
            cimone::arch::platform::mcv2_pioneer(),
            1,
            64,
        ),
    );
    let eff = |name: &str| report.outcome(name).unwrap().hpl_gflops / (8.0 * single);
    let (gbe, ten, over) = (eff("gbe-flat"), eff("ten-gbe-flat"), eff("gbe-8to1"));
    assert!((0.15..0.50).contains(&gbe), "MCv2 8-node on 1 GbE: {gbe:.3}");
    assert!((0.65..1.0).contains(&ten), "MCv2 8-node on 10 GbE: {ten:.3}");
    // the oversubscribed custom fabric is the worst of the three
    assert!(over < gbe, "8:1 oversub {over:.3} !< flat {gbe:.3}");

    // every scenario really ran (scheduled makespan, validated numerics)
    for o in &report.scenarios {
        assert!(o.makespan_s > 0.0, "{}", o.name);
    }

    // bit-for-bit rerun of the full pipeline
    let rerun = run_matrix(&matrix).unwrap();
    assert_eq!(rerun, report);
}

const SWEEP_SPEC: &str = r#"
# MCv1-vs-MCv2 generation matrix (the paper's headline comparison)
[campaign]
validate_n = 48

[[workload]]
kind = "stream"
name = "stream"
platform = "mcv2-dual"
partition = "mcv2"
threads = 64

[[workload]]
kind = "hpl"
name = "hpl"
platform = "mcv2-dual"
partition = "mcv2"
cores_per_node = 128

[matrix]
platforms = ["mcv1-u740", "mcv2-dual"]
"#;

#[test]
fn sweep_spec_file_runs_end_to_end_with_the_paper_ratios() {
    let path = std::env::temp_dir().join("cimone_integration_sweep.toml");
    fs::write(&path, SWEEP_SPEC).unwrap();
    let matrix = ScenarioMatrix::load(path.to_str().unwrap()).unwrap();
    let _ = fs::remove_file(&path);

    let report = run_matrix(&matrix).unwrap();
    assert_eq!(report.scenarios.len(), 2);
    let dual = report.outcome("mcv2-dual").unwrap();
    let (hpl_x, stream_x) = report.speedup_of(dual);
    let (hpl_x, stream_x) = (hpl_x.unwrap(), stream_x.unwrap());
    assert!((100.0..160.0).contains(&hpl_x), "~127x HPL, got {hpl_x:.0}x");
    assert!((55.0..85.0).contains(&stream_x), "~69x STREAM, got {stream_x:.0}x");

    // the JSON export of the same report parses and carries the ratios
    let parsed = Json::parse(&report.to_json().render()).unwrap();
    let rows = parsed.get("scenarios").unwrap().as_arr().unwrap();
    let dual_row = rows
        .iter()
        .find(|r| r.get("name").unwrap().as_str() == Some("mcv2-dual"))
        .unwrap();
    let jx = dual_row.get("hpl_speedup").unwrap().as_f64().unwrap();
    assert!((jx - hpl_x).abs() < 1e-9, "{jx} vs {hpl_x}");
}

#[test]
fn unknown_axis_values_in_spec_files_are_typed_errors() {
    // a platform the registry has never heard of
    let bad = SWEEP_SPEC.replace("\"mcv1-u740\"", "\"epyc-9654\"");
    match ScenarioMatrix::parse(&bad) {
        Err(CimoneError::UnknownPlatform { id, known }) => {
            assert_eq!(id, "epyc-9654");
            assert!(known.contains("mcv2-dual"), "{known}");
        }
        other => panic!("expected UnknownPlatform, got {other:?}"),
    }
    // an unknown BLAS library on the libs axis
    let bad = SWEEP_SPEC.replace(
        "platforms = [\"mcv1-u740\", \"mcv2-dual\"]",
        "libs = [\"mkl\"]",
    );
    assert!(matches!(
        ScenarioMatrix::parse(&bad),
        Err(CimoneError::Spec(ref m)) if m.contains("unknown library `mkl`")
    ));
    // a workload-subset filter that selects nothing
    let bad = format!("{SWEEP_SPEC}workloads = [\"dgemm-*\"]\n");
    assert!(matches!(
        ScenarioMatrix::parse(&bad),
        Err(CimoneError::Spec(ref m)) if m.contains("matches nothing")
    ));
}

// ---------------------------------------------------------------------
// golden regression: the power-cap operating-point matrix
// ---------------------------------------------------------------------

#[test]
fn golden_power_cap_matrix_names_each_generations_operating_point() {
    let report = dry_run_matrix(&ScenarioMatrix::power_cap()).unwrap();
    assert_eq!(report.scenarios.len(), 30, "5 generations x 2 node counts x 3 caps");
    assert_eq!(report.total, 30);

    // the cap in every scenario name binds: the active-core clamp keeps
    // the affine power model at or under the cap
    for o in &report.scenarios {
        let cap: f64 = o
            .name
            .rsplit("/cap")
            .next()
            .and_then(|s| s.strip_suffix('W'))
            .and_then(|s| s.parse().ok())
            .unwrap_or_else(|| panic!("no cap in `{}`", o.name));
        assert!(o.avg_node_w <= cap + 1e-9, "{}: {} W over the {cap} W cap", o.name, o.avg_node_w);
        assert!(o.hpl_gflops > 0.0 && o.gflops_per_w > 0.0, "{}", o.name);
    }

    // each generation has six candidate points and a best GF/s-per-W
    // operating point among them; loosening the cap never costs FLOP/s
    for p in ["mcv1-u740", "mcv2-pioneer", "mcv2-dual", "sg2044", "mcv3"] {
        let points: Vec<_> =
            report.scenarios.iter().filter(|o| o.name.starts_with(&format!("{p}/"))).collect();
        assert_eq!(points.len(), 6, "{p}");
        let best = points.iter().max_by(|a, b| a.gflops_per_w.total_cmp(&b.gflops_per_w)).unwrap();
        assert!(points.iter().all(|o| o.gflops_per_w <= best.gflops_per_w), "{p}");
        let gf = |name: String| report.outcome(&name).unwrap().hpl_gflops;
        assert!(
            gf(format!("{p}/1n/cap120W")) <= gf(format!("{p}/1n/cap250W")) + 1e-9,
            "{p}: a tighter cap must not raise FLOP/s"
        );
    }

    // the tight cap visibly bites on the hungriest node: MCv2-dual idles
    // at 110 W, so 120 W leaves room for exactly 7 active cores...
    let dual = report.outcome("mcv2-dual/1n/cap120W").unwrap();
    assert!((dual.avg_node_w - (110.0 + 1.4 * 7.0)).abs() < 1e-9, "{}", dual.avg_node_w);
    let open = report.outcome("mcv2-dual/1n/cap250W").unwrap();
    assert!(dual.hpl_gflops < open.hpl_gflops, "the 120 W clamp must cost FLOP/s");
    // ...while MCv1's four little cores fit under every cap, so its rows
    // only differ in name
    let m1 = |c: &str| report.outcome(&format!("mcv1-u740/1n/cap{c}W")).unwrap();
    assert_eq!(m1("120").hpl_gflops.to_bits(), m1("250").hpl_gflops.to_bits());
    assert_eq!(m1("120").avg_node_w.to_bits(), m1("180").avg_node_w.to_bits());

    // bit-identical rerun: the operating points cannot wander
    let rerun = dry_run_matrix(&ScenarioMatrix::power_cap()).unwrap();
    assert_eq!(rerun, report);
}

// ---------------------------------------------------------------------
// equivalence properties
// ---------------------------------------------------------------------

/// An oversubscribed campaign on a 3-node fleet of one platform: enough
/// competing jobs that queueing and backfill both engage.
fn platform_campaign(platform_id: &str) -> CampaignSpec {
    let reg = PlatformRegistry::builtin();
    let p = reg.get(platform_id).unwrap();
    let cores = p.desc.total_cores();
    let mut spec = CampaignSpec::new();
    spec.fleet = vec![(p.id.clone(), 3)];
    for (i, nodes) in [(0usize, 2usize), (1, 1), (2, 3), (3, 1)] {
        spec.push(WorkloadSpec::Hpl {
            name: format!("hpl-{i}"),
            partition: p.partition.clone(),
            nodes,
            platform: p.id.clone(),
            cluster_nodes: nodes,
            cores_per_node: cores,
            lib: None,
            fabric: None,
        });
    }
    spec.push(WorkloadSpec::Stream {
        name: "stream-0".into(),
        partition: p.partition.clone(),
        nodes: 1,
        platform: p.id.clone(),
        threads: cores,
    });
    spec
}

/// Submit a spec's estimated jobs into a fresh scheduler for the fleet.
fn loaded_scheduler(spec: &CampaignSpec) -> cimone::sched::Scheduler {
    let inv = spec.build_inventory().unwrap();
    let mut sched = inv.scheduler();
    for ws in &spec.workloads {
        let w = ws.build();
        let est = w.estimate(&inv).unwrap();
        sched.submit(w.name(), w.partition(), w.nodes(), est.runtime_s).unwrap();
    }
    sched
}

#[test]
fn parallel_and_serial_drain_agree_for_every_builtin_platform() {
    let reg = PlatformRegistry::builtin();
    for id in reg.ids() {
        let spec = platform_campaign(&id);
        let mut serial = loaded_scheduler(&spec);
        let mut parallel = loaded_scheduler(&spec);
        let m1 = serial.drain();
        let m2 = parallel.drain_parallel();
        assert_eq!(m1, m2, "{id}: makespan diverged");
        assert_eq!(serial.jobs.len(), parallel.jobs.len());
        for (a, b) in serial.jobs.iter().zip(&parallel.jobs) {
            assert_eq!(a.id, b.id, "{id}");
            assert_eq!(a.state, b.state, "{id}: job `{}` diverged", a.name);
            assert_eq!(a.allocated, b.allocated, "{id}: job `{}` allocation", a.name);
        }
    }
}

#[test]
fn parallel_drain_matches_serial_on_a_mixed_generation_fleet() {
    // four independent partitions, each oversubscribed: the fan-out case
    // drain_parallel exists for
    let mut spec = CampaignSpec::new();
    spec.fleet = vec![
        ("mcv1-u740".into(), 2),
        ("mcv2-pioneer".into(), 2),
        ("mcv2-dual".into(), 1),
        ("sg2044".into(), 2),
        ("mcv3".into(), 2),
    ];
    for (platform, partition, cores) in [
        ("mcv1-u740", "mcv1", 4usize),
        ("mcv2-pioneer", "mcv2", 64),
        ("sg2044", "sg2044", 64),
        ("mcv3", "mcv3", 128),
    ] {
        for i in 0..3usize {
            spec.push(WorkloadSpec::Hpl {
                name: format!("hpl-{platform}-{i}"),
                partition: partition.into(),
                nodes: 1 + i % 2,
                platform: platform.into(),
                cluster_nodes: 1 + i % 2,
                cores_per_node: cores,
                lib: None,
                fabric: None,
            });
        }
    }
    let mut serial = loaded_scheduler(&spec);
    let mut parallel = loaded_scheduler(&spec);
    let m1 = serial.drain();
    let m2 = parallel.drain_parallel();
    assert_eq!(m1, m2);
    for (a, b) in serial.jobs.iter().zip(&parallel.jobs) {
        assert_eq!((a.id, &a.state, &a.allocated), (b.id, &b.state, &b.allocated));
    }
}

#[test]
fn scenario_fan_out_is_order_independent() {
    let gens = ["mcv1-u740", "mcv2-pioneer", "sg2044"];
    let matrix_of = |order: &[&str]| {
        let mut m = ScenarioMatrix::generations();
        // explicit scenarios in the given order instead of the axis
        m.axes = MatrixAxes::default();
        m.scenarios = order
            .iter()
            .map(|id| ScenarioSpec {
                name: id.to_string(),
                platform: Some(id.to_string()),
                ..ScenarioSpec::default()
            })
            .collect();
        m
    };
    let forward = run_matrix(&matrix_of(&gens)).unwrap();
    let mut shuffled = gens;
    shuffled.reverse();
    let backward = run_matrix(&matrix_of(&shuffled)).unwrap();
    let rotated = run_matrix(&matrix_of(&[gens[1], gens[2], gens[0]])).unwrap();

    // report rows follow matrix order...
    let names: Vec<&str> = backward.scenarios.iter().map(|o| o.name.as_str()).collect();
    assert!(names.starts_with(&["sg2044", "mcv2-pioneer", "mcv1-u740"]), "{names:?}");
    // ...but each scenario's outcome is identical whatever ran beside it
    for id in gens {
        let a = forward.outcome(id).unwrap();
        assert_eq!(a, backward.outcome(id).unwrap(), "{id} diverged under reversal");
        assert_eq!(a, rotated.outcome(id).unwrap(), "{id} diverged under rotation");
    }
}

// ---------------------------------------------------------------------
// spec render round-trips
// ---------------------------------------------------------------------

#[test]
fn campaign_and_matrix_specs_round_trip_through_render() {
    // campaign side: [[platform]] override + [[fleet]] + every workload kind
    let campaign_text = r#"
[campaign]
validate_n = 48

[[fabric]]
id = "ten-gbe-oversub"
base = "ten-gbe-flat"
backplane_factor = 0.5
ports = 48

[[platform]]
id = "sg2044-oc"
base = "sg2044"
freq_ghz = 3.0
idle_w = 70.0
default_fabric = "ten-gbe-oversub"

[[fleet]]
platform = "sg2044-oc"
count = 4

[[workload]]
kind = "stream"
name = "s"
platform = "sg2044-oc"
partition = "sg2044"
threads = 64

[[workload]]
kind = "hpl"
name = "h"
platform = "sg2044-oc"
partition = "sg2044"
nodes = 2
cores_per_node = 64
lib = "openblas-c920"
fabric = "ten-gbe-oversub"

[[workload]]
kind = "blis-ablation"
name = "b"
partition = "mcv2"
lib = "blis-opt"
runtime_s = 120.5
"#;
    let spec = CampaignSpec::parse(campaign_text).unwrap();
    // the [[fabric]] section landed in the spec and the custom platform
    // points its default at it
    assert_eq!(spec.custom_fabrics.len(), 1);
    assert_eq!(spec.build_inventory().unwrap().fabric.id, "ten-gbe-oversub");
    let back = CampaignSpec::parse(&spec.render()).unwrap();
    assert_eq!(back, spec);

    // matrix side: the same base plus axes (fabrics included) and an
    // explicit scenario pinning its own interconnect
    let matrix_text = format!(
        "{campaign_text}\n[matrix]\nplatforms = [\"mcv1-u740\", \"mcv2-dual\"]\nworkloads = [\"hpl\"]\n\
         fabrics = [\"gbe-flat\", \"ten-gbe-oversub\"]\n\n\
         [[scenario]]\nname = \"oc-rack\"\nplatform = \"sg2044-oc\"\ncount = 4\nnodes = 4\nlib = \"blis-lmul4\"\n\
         fabric = \"ten-gbe-oversub\"\n"
    );
    let matrix = ScenarioMatrix::parse(&matrix_text).unwrap();
    let back = ScenarioMatrix::parse(&matrix.render()).unwrap();
    assert_eq!(back, matrix);

    // and both built-in matrices round-trip too
    let gens = ScenarioMatrix::generations();
    assert_eq!(ScenarioMatrix::parse(&gens.render()).unwrap(), gens);
    let fs = ScenarioMatrix::fabric_scaling();
    assert_eq!(ScenarioMatrix::parse(&fs.render()).unwrap(), fs);
}
