//! Scheduler equivalence and safety properties, end to end on the
//! paper's 12-node machine: the serial [`Scheduler::drain`] and the
//! concurrent per-partition [`Scheduler::drain_parallel`] must agree
//! bit for bit on seeded random multi-user queues, a partition must
//! never be oversubscribed, no job may start before it arrives — and a
//! 10,000-job production queue drains deterministically.

use cimone::cluster::monte_cimone_v2;
use cimone::sched::{JobRequest, JobState, Scheduler};
use cimone::util::rng::Rng;

fn paper_scheduler() -> Scheduler {
    monte_cimone_v2().scheduler()
}

/// A seeded random multi-user queue over both paper partitions: mixed
/// widths, runtimes, arrival times, priorities and users — enough
/// contention that queueing and backfill both engage.
fn random_queue(seed: u64, n_jobs: usize) -> Vec<JobRequest> {
    let mut rng = Rng::new(seed);
    let mut reqs = Vec::with_capacity(n_jobs);
    for i in 0..n_jobs {
        let (partition, cap) = if rng.below(2) == 0 { ("mcv1", 8) } else { ("mcv2", 4) };
        let nodes = rng.range_usize(1, cap + 1);
        let runtime_s = rng.range_f64(1.0, 500.0);
        let arrival_s = rng.range_f64(0.0, 300.0);
        let priority = rng.below(3) as i64;
        let user = format!("user{}", rng.below(4));
        reqs.push(
            JobRequest::new(format!("job-{i}"), partition, nodes, runtime_s)
                .arriving_at(arrival_s)
                .with_priority(priority)
                .with_user(user),
        );
    }
    reqs
}

/// Exact `(name, start, end)` of every job; panics on a job that never
/// completed (a drain must finish everything).
fn completed_spans(s: &Scheduler) -> Vec<(String, f64, f64)> {
    s.jobs
        .iter()
        .map(|j| match j.state {
            JobState::Completed { start, end } => (j.name.clone(), start, end),
            other => panic!("job `{}` did not complete: {other:?}", j.name),
        })
        .collect()
}

#[test]
fn serial_and_parallel_drains_agree_bit_for_bit() {
    for seed in 0..20u64 {
        let mut serial = paper_scheduler();
        for r in random_queue(seed, 60) {
            serial.submit_request(r).unwrap();
        }
        let mut parallel = paper_scheduler();
        for r in random_queue(seed, 60) {
            parallel.submit_request(r).unwrap();
        }
        let m_serial = serial.drain();
        let m_parallel = parallel.drain_parallel();
        // exact bits: with end times stored once, there is no epsilon
        // for the two drain orders to disagree on
        assert_eq!(m_serial.to_bits(), m_parallel.to_bits(), "seed {seed}");
        assert_eq!(completed_spans(&serial), completed_spans(&parallel), "seed {seed}");
    }
}

#[test]
fn no_oversubscription_and_no_early_starts() {
    for seed in [1u64, 7, 13] {
        let mut s = paper_scheduler();
        for r in random_queue(seed, 80) {
            s.submit_request(r).unwrap();
        }
        s.drain();
        for j in &s.jobs {
            let JobState::Completed { start, .. } = j.state else {
                panic!("job `{}` did not complete", j.name);
            };
            assert!(start >= j.submit_s, "`{}` started {start} before arrival {}", j.name, j.submit_s);
        }
        // at every job start, concurrently running jobs of the same
        // partition can never exceed its node count
        for (partition, cap) in [("mcv1", 8usize), ("mcv2", 4)] {
            let spans: Vec<(f64, f64, usize)> = s
                .jobs
                .iter()
                .filter(|j| j.partition == partition)
                .map(|j| match j.state {
                    JobState::Completed { start, end } => (start, end, j.nodes),
                    _ => unreachable!(),
                })
                .collect();
            for &(t, _, _) in &spans {
                let used: usize = spans
                    .iter()
                    .filter(|(start, end, _)| *start <= t && t < *end)
                    .map(|(_, _, nodes)| nodes)
                    .sum();
                assert!(
                    used <= cap,
                    "seed {seed}: partition `{partition}` holds {used} > {cap} nodes at t={t}"
                );
            }
        }
    }
}

/// The production-scale acceptance case: 10,000 jobs across four users,
/// drained by the event-driven scheduler, with a bit-identical rerun.
#[test]
fn ten_thousand_job_queue_drains_deterministically() {
    let build = || {
        let mut s = paper_scheduler();
        let mut rng = Rng::new(99);
        for i in 0..10_000usize {
            let user = ["alice", "bob", "carol", "dave"][rng.below(4) as usize];
            let partition = if rng.below(4) == 0 { "mcv2" } else { "mcv1" };
            let nodes = rng.range_usize(1, 3);
            let runtime_s = rng.range_f64(5.0, 50.0);
            let arrival_s = rng.range_f64(0.0, 40_000.0);
            s.submit_request(
                JobRequest::new(format!("{user}/job.{i}"), partition, nodes, runtime_s)
                    .arriving_at(arrival_s)
                    .with_priority(rng.below(2) as i64)
                    .with_user(user),
            )
            .unwrap();
        }
        s
    };
    let mut a = build();
    let makespan = a.drain_parallel();
    assert_eq!(a.jobs.len(), 10_000);
    let spans = completed_spans(&a); // panics if anything is left behind
    let latest_arrival = a.jobs.iter().map(|j| j.submit_s).fold(0.0, f64::max);
    assert!(makespan.is_finite() && makespan >= latest_arrival);

    let mut b = build();
    assert_eq!(b.drain_parallel().to_bits(), makespan.to_bits());
    assert_eq!(completed_spans(&b), spans);
}
